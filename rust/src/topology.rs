//! Decentralized-training topologies and their mixing weights.
//!
//! The paper models the worker fleet as an undirected graph `G = (V, W)`
//! with a symmetric doubly-stochastic `W` (Assumption 1); all convergence
//! constants enter through the spectral gap `rho = 1 - |lambda_2(W)|`
//! (Lemma 1). This module builds the standard families — the paper's
//! ring, plus chain/complete/star/2-D torus/hypercube/exponential-graph/
//! random-regular for the topology ablation and fleet-scale runs — and
//! two weighting schemes (uniform-degree as used in the paper's 1/3-ring,
//! and Metropolis–Hastings for irregular graphs).
//!
//! Weights come in two representations: the dense [`Mat`] from
//! [`mixing_matrix`] (display, small-K analysis) and the sparse
//! [`MixWeights`] CSR rows (the hot path — gossip at K=1024 touches
//! O(K·deg) weights, never a K×K matrix). [`MixWeights::from_graph`]
//! derives the SAME f64 values as the dense path, bit for bit, so
//! switching representations never perturbs a trajectory (DESIGN.md §8).

use crate::linalg::{self, Mat};
use crate::rng::Xoshiro256;

/// Undirected simple graph on `[0, k)` as adjacency lists.
#[derive(Clone, Debug)]
pub struct Graph {
    pub k: usize,
    adj: Vec<Vec<usize>>,
}

impl Graph {
    pub fn empty(k: usize) -> Self {
        Self { k, adj: vec![Vec::new(); k] }
    }

    pub fn add_edge(&mut self, i: usize, j: usize) {
        assert!(i != j && i < self.k && j < self.k, "bad edge ({i},{j})");
        if !self.adj[i].contains(&j) {
            self.adj[i].push(j);
            self.adj[j].push(i);
        }
    }

    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.adj[i]
    }

    pub fn degree(&self, i: usize) -> usize {
        self.adj[i].len()
    }

    pub fn edge_count(&self) -> usize {
        self.adj.iter().map(|a| a.len()).sum::<usize>() / 2
    }

    /// Connectivity via BFS — every topology we hand to an algorithm must
    /// be connected or consensus is impossible (rho = 0).
    pub fn is_connected(&self) -> bool {
        if self.k == 0 {
            return true;
        }
        let mut seen = vec![false; self.k];
        let mut queue = vec![0usize];
        seen[0] = true;
        while let Some(i) = queue.pop() {
            for &j in &self.adj[i] {
                if !seen[j] {
                    seen[j] = true;
                    queue.push(j);
                }
            }
        }
        seen.into_iter().all(|s| s)
    }
}

/// Topology families. `Ring` with K=8 is the paper's experimental setup.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// Cycle: worker k talks to k±1 (mod K). The paper's setup.
    Ring,
    /// Path: like Ring without the wrap-around edge (worst-case rho).
    Chain,
    /// All-to-all. rho = 1: decentralized == centralized averaging.
    Complete,
    /// Hub-and-spoke around worker 0.
    Star,
    /// 2-D torus on an r x c grid (requires K = r*c with r,c >= 2).
    Torus2d,
    /// Hypercube (requires K a power of two).
    Hypercube,
    /// Exponential graph: worker i links to (i ± 2^s) mod K for every
    /// power 2^s < K. Degree ~2·log2(K), spectral gap O(1/log K) — the
    /// standard fleet-scale topology (Assran et al.'s SGP uses it).
    ExpGraph,
    /// Random d-regular graph (configuration model with retries).
    RandomRegular { degree: usize },
}

impl Topology {
    pub fn parse(s: &str) -> Option<Topology> {
        match s {
            "ring" => Some(Topology::Ring),
            "chain" => Some(Topology::Chain),
            "complete" | "full" => Some(Topology::Complete),
            "star" => Some(Topology::Star),
            "torus" | "torus2d" => Some(Topology::Torus2d),
            "hypercube" => Some(Topology::Hypercube),
            "expgraph" | "exponential" => Some(Topology::ExpGraph),
            _ => s
                .strip_prefix("random-regular:")
                .or_else(|| s.strip_prefix("regular-"))
                .and_then(|d| d.parse().ok().map(|degree| Topology::RandomRegular { degree })),
        }
    }

    /// Feasibility check for a (topology, K) pair — the CLI/config layer
    /// surfaces these as user errors instead of panics deep in `build`.
    pub fn validate(self, k: usize) -> Result<(), String> {
        if k == 0 {
            return Err("need at least one worker".into());
        }
        if k == 1 {
            return Ok(()); // every family degenerates to the single node
        }
        match self {
            Topology::Torus2d => torus_dims(k).map(|_| ()).ok_or_else(|| {
                format!("torus requires K = r*c with r,c >= 2; K={k} has no such factorization")
            }),
            Topology::Hypercube => {
                if k.is_power_of_two() {
                    Ok(())
                } else {
                    Err(format!("hypercube requires K = 2^n, got K={k}"))
                }
            }
            Topology::RandomRegular { degree } => {
                if degree < 2 {
                    Err(format!("random-regular requires degree >= 2, got {degree}"))
                } else if degree >= k {
                    Err(format!("random-regular degree {degree} must be < K={k}"))
                } else if (k * degree) % 2 != 0 {
                    Err(format!(
                        "random-regular requires even K*degree (handshake lemma); \
                         K={k} * degree={degree} is odd"
                    ))
                } else {
                    Ok(())
                }
            }
            _ => Ok(()),
        }
    }

    pub fn build(self, k: usize, seed: u64) -> Graph {
        if let Err(e) = self.validate(k) {
            panic!("{e}");
        }
        let mut g = Graph::empty(k);
        if k == 1 {
            return g;
        }
        match self {
            Topology::Ring => {
                for i in 0..k {
                    g.add_edge(i, (i + 1) % k);
                }
            }
            Topology::Chain => {
                for i in 0..k - 1 {
                    g.add_edge(i, i + 1);
                }
            }
            Topology::Complete => {
                for i in 0..k {
                    for j in (i + 1)..k {
                        g.add_edge(i, j);
                    }
                }
            }
            Topology::Star => {
                for i in 1..k {
                    g.add_edge(0, i);
                }
            }
            Topology::Torus2d => {
                let (r, c) = torus_dims(k).expect("validated above");
                for i in 0..r {
                    for j in 0..c {
                        let id = i * c + j;
                        g.add_edge(id, i * c + (j + 1) % c);
                        g.add_edge(id, ((i + 1) % r) * c + j);
                    }
                }
            }
            Topology::Hypercube => {
                let bits = k.trailing_zeros();
                for i in 0..k {
                    for b in 0..bits {
                        let j = i ^ (1 << b);
                        if j > i {
                            g.add_edge(i, j);
                        }
                    }
                }
            }
            Topology::ExpGraph => {
                for i in 0..k {
                    let mut s = 1usize;
                    while s < k {
                        g.add_edge(i, (i + s) % k);
                        s <<= 1;
                    }
                }
            }
            Topology::RandomRegular { degree } => {
                g = random_regular(k, degree, seed);
            }
        }
        debug_assert!(g.is_connected(), "{self:?} built a disconnected graph");
        g
    }
}

/// Factor K as r*c with both >= 2 and as square as possible.
fn torus_dims(k: usize) -> Option<(usize, usize)> {
    let mut best = None;
    let mut r = (k as f64).sqrt() as usize;
    while r >= 2 {
        if k % r == 0 && k / r >= 2 {
            best = Some((r, k / r));
            break;
        }
        r -= 1;
    }
    best
}

/// Configuration-model random d-regular graph; retries until simple and
/// connected (fast for the K <= 64 sizes we use).
fn random_regular(k: usize, degree: usize, seed: u64) -> Graph {
    assert!(degree >= 2 && degree < k && (k * degree) % 2 == 0,
            "invalid (K={k}, degree={degree}) for a regular graph");
    let mut rng = Xoshiro256::seed_from_u64(seed);
    'attempt: for _ in 0..10_000 {
        let mut stubs: Vec<usize> = (0..k).flat_map(|i| std::iter::repeat(i).take(degree)).collect();
        rng.shuffle(&mut stubs);
        let mut g = Graph::empty(k);
        for pair in stubs.chunks(2) {
            let (a, b) = (pair[0], pair[1]);
            if a == b || g.neighbors(a).contains(&b) {
                continue 'attempt; // multi-edge or loop: resample
            }
            g.add_edge(a, b);
        }
        if g.is_connected() {
            return g;
        }
    }
    panic!("failed to sample a connected {degree}-regular graph on {k} nodes");
}

/// Mixing-weight schemes for turning a graph into W.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Weighting {
    /// w_ij = 1/(deg_max + 1) off-diagonal, remainder on the diagonal.
    /// For the ring this is the paper's (1/3, 1/3, 1/3).
    UniformDegree,
    /// Metropolis–Hastings: w_ij = 1/(1 + max(deg_i, deg_j)); always
    /// doubly stochastic on irregular graphs (star, random).
    Metropolis,
    /// Lazy Metropolis: (I + W_mh)/2 — guarantees lambda_n > 0 so
    /// |lambda_2| is the relevant eigenvalue even on bipartite graphs.
    LazyMetropolis,
}

/// Build the doubly-stochastic mixing matrix for `g` under `scheme`.
pub fn mixing_matrix(g: &Graph, scheme: Weighting) -> Mat {
    let k = g.k;
    let mut w = Mat::zeros(k, k);
    if k == 1 {
        w[(0, 0)] = 1.0;
        return w;
    }
    match scheme {
        Weighting::UniformDegree => {
            let dmax = (0..k).map(|i| g.degree(i)).max().unwrap();
            let wij = 1.0 / (dmax as f64 + 1.0);
            for i in 0..k {
                for &j in g.neighbors(i) {
                    w[(i, j)] = wij;
                }
                w[(i, i)] = 1.0 - wij * g.degree(i) as f64;
            }
        }
        Weighting::Metropolis | Weighting::LazyMetropolis => {
            for i in 0..k {
                for &j in g.neighbors(i) {
                    w[(i, j)] = 1.0 / (1.0 + g.degree(i).max(g.degree(j)) as f64);
                }
            }
            for i in 0..k {
                let off: f64 = (0..k).filter(|&j| j != i).map(|j| w[(i, j)]).sum();
                w[(i, i)] = 1.0 - off;
            }
            if scheme == Weighting::LazyMetropolis {
                for i in 0..k {
                    for j in 0..k {
                        w[(i, j)] *= 0.5;
                    }
                    w[(i, i)] += 0.5;
                }
            }
        }
    }
    debug_assert!(w.is_doubly_stochastic(1e-9));
    w
}

/// Sparse symmetric doubly-stochastic mixing weights: one CSR row per
/// RECEIVER holding its `(neighbor, weight)` entries in ascending
/// neighbor order, plus the diagonal self-weight kept separately for
/// O(1) access. This is the hot-path representation — gossip at K=1024
/// walks O(K·deg) entries where the dense [`Mat`] walks K².
///
/// Two invariants matter for bit-identity (DESIGN.md §8):
/// * [`MixWeights::from_graph`] computes each f64 weight with exactly
///   the operations (and accumulation order) of [`mixing_matrix`], so
///   sparse and dense derivations agree bit for bit;
/// * entries are ascending by neighbor index, matching the
///   ascending-sender inbox order of [`crate::comm::Network`], so the
///   gossip accumulation visits terms in the same order the dense scan
///   did.
#[derive(Clone, Debug, PartialEq)]
pub struct MixWeights {
    k: usize,
    /// Row extents: receiver i's entries are `entries[row_ptr[i]..row_ptr[i+1]]`.
    row_ptr: Vec<usize>,
    /// Off-diagonal `(neighbor, weight)` pairs, ascending per row.
    entries: Vec<(usize, f64)>,
    /// Self-weights w_ii.
    diag: Vec<f64>,
}

impl MixWeights {
    /// W = I (the no-mixing default of `AlgorithmSpec`).
    pub fn identity(k: usize) -> Self {
        Self { k, row_ptr: vec![0; k + 1], entries: Vec::new(), diag: vec![1.0; k] }
    }

    /// Derive the weights for `g` under `scheme` WITHOUT materializing a
    /// dense matrix — same f64 values as [`mixing_matrix`], bit for bit
    /// (property-tested below).
    pub fn from_graph(g: &Graph, scheme: Weighting) -> Self {
        let k = g.k;
        if k == 1 {
            return Self::identity(1);
        }
        let sorted: Vec<Vec<usize>> = (0..k)
            .map(|i| {
                let mut v = g.neighbors(i).to_vec();
                v.sort_unstable();
                v
            })
            .collect();
        let mut row_ptr = Vec::with_capacity(k + 1);
        row_ptr.push(0usize);
        let mut entries = Vec::new();
        let mut diag = vec![0.0f64; k];
        match scheme {
            Weighting::UniformDegree => {
                let dmax = (0..k).map(|i| g.degree(i)).max().unwrap();
                let wij = 1.0 / (dmax as f64 + 1.0);
                for i in 0..k {
                    for &j in &sorted[i] {
                        entries.push((j, wij));
                    }
                    diag[i] = 1.0 - wij * g.degree(i) as f64;
                    row_ptr.push(entries.len());
                }
            }
            Weighting::Metropolis | Weighting::LazyMetropolis => {
                let lazy = scheme == Weighting::LazyMetropolis;
                for i in 0..k {
                    let start = entries.len();
                    // Ascending-j accumulation matches the dense row sum
                    // (absent entries add literal +0.0 there — a no-op).
                    let mut off = 0.0f64;
                    for &j in &sorted[i] {
                        let w = 1.0 / (1.0 + g.degree(i).max(g.degree(j)) as f64);
                        off += w;
                        entries.push((j, w));
                    }
                    let mut d = 1.0 - off;
                    if lazy {
                        for e in &mut entries[start..] {
                            e.1 *= 0.5;
                        }
                        d = d * 0.5 + 0.5;
                    }
                    diag[i] = d;
                    row_ptr.push(entries.len());
                }
            }
        }
        Self { k, row_ptr, entries, diag }
    }

    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// w_ii.
    #[inline]
    pub fn self_weight(&self, i: usize) -> f64 {
        self.diag[i]
    }

    /// Receiver i's off-diagonal `(neighbor, weight)` entries, ascending.
    #[inline]
    pub fn neighbors(&self, i: usize) -> &[(usize, f64)] {
        &self.entries[self.row_ptr[i]..self.row_ptr[i + 1]]
    }

    /// Off-diagonal degree of receiver i.
    #[inline]
    pub fn degree(&self, i: usize) -> usize {
        self.row_ptr[i + 1] - self.row_ptr[i]
    }

    /// Total off-diagonal entries.
    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    /// w_ij by binary search (diagnostics / symmetry checks — hot paths
    /// walk [`MixWeights::neighbors`] or a [`RowCursor`] instead).
    pub fn weight(&self, i: usize, j: usize) -> f64 {
        if i == j {
            return self.diag[i];
        }
        let row = self.neighbors(i);
        match row.binary_search_by_key(&j, |e| e.0) {
            Ok(p) => row[p].1,
            Err(_) => 0.0,
        }
    }

    /// Forward-only weight lookup for callers that visit senders in
    /// ascending order (the gossip inbox invariant).
    pub fn row_cursor(&self, i: usize) -> RowCursor<'_> {
        RowCursor { row: self.neighbors(i), pos: 0 }
    }

    /// Assumption 1 check in O(nnz): symmetric, rows sum to 1, entries
    /// in [0,1] (symmetry + row-stochastic implies column-stochastic).
    pub fn is_doubly_stochastic(&self, tol: f64) -> bool {
        for i in 0..self.k {
            let s = self.diag[i] + self.neighbors(i).iter().map(|e| e.1).sum::<f64>();
            if (s - 1.0).abs() > tol || !(-tol..=1.0 + tol).contains(&self.diag[i]) {
                return false;
            }
            for &(j, w) in self.neighbors(i) {
                if !(-tol..=1.0 + tol).contains(&w) || (self.weight(j, i) - w).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Densify (display / small-K analysis only).
    pub fn to_mat(&self) -> Mat {
        let mut m = Mat::zeros(self.k, self.k);
        for i in 0..self.k {
            m[(i, i)] = self.diag[i];
            for &(j, w) in self.neighbors(i) {
                m[(i, j)] = w;
            }
        }
        m
    }

    /// y = W x in O(nnz), visiting each row's terms in ascending column
    /// order with the diagonal at its natural position (mirrors the
    /// dense row scan).
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.k);
        assert_eq!(y.len(), self.k);
        for i in 0..self.k {
            let mut acc = 0.0f64;
            let mut diag_done = false;
            for &(j, w) in self.neighbors(i) {
                if j > i && !diag_done {
                    acc += self.diag[i] * x[i];
                    diag_done = true;
                }
                acc += w * x[j];
            }
            if !diag_done {
                acc += self.diag[i] * x[i];
            }
            y[i] = acc;
        }
    }

    /// Spectral gap rho = 1 - |lambda_2(W)| via sparse power iteration —
    /// no dense K×K materialization at K=1024.
    pub fn spectral_gap(&self, seed: u64) -> f64 {
        linalg::spectral_gap_op(self.k, |x, y| self.matvec_into(x, y), seed)
    }
}

/// Forward-only cursor over one ascending CSR row; absent columns read
/// as weight 0.0 (the dense-lookup semantics).
pub struct RowCursor<'a> {
    row: &'a [(usize, f64)],
    pos: usize,
}

impl RowCursor<'_> {
    /// Weight toward column `j`; calls must present `j` in ascending
    /// order across the cursor's lifetime.
    #[inline]
    pub fn weight(&mut self, j: usize) -> f64 {
        while self.pos < self.row.len() && self.row[self.pos].0 < j {
            self.pos += 1;
        }
        match self.row.get(self.pos) {
            Some(&(jj, w)) if jj == j => w,
            _ => 0.0,
        }
    }
}

impl From<&Mat> for MixWeights {
    /// Sparsify a dense mixing matrix (legacy call sites, hand-built
    /// test matrices). Off-diagonal zeros are dropped; weights are kept
    /// bit-exact.
    fn from(w: &Mat) -> Self {
        assert_eq!(w.rows, w.cols, "mixing matrix must be square");
        let k = w.rows;
        let mut row_ptr = Vec::with_capacity(k + 1);
        row_ptr.push(0usize);
        let mut entries = Vec::new();
        let mut diag = vec![0.0f64; k];
        for i in 0..k {
            for j in 0..k {
                let wij = w[(i, j)];
                if i == j {
                    diag[i] = wij;
                } else if wij != 0.0 {
                    entries.push((j, wij));
                }
            }
            row_ptr.push(entries.len());
        }
        Self { k, row_ptr, entries, diag }
    }
}

impl From<Mat> for MixWeights {
    fn from(w: Mat) -> Self {
        (&w).into()
    }
}

/// Convenience: (graph, W, rho) for a named topology — DENSE weights;
/// display and small-K analysis only. The driver uses [`build_sparse`].
pub fn build(topology: Topology, k: usize, scheme: Weighting, seed: u64) -> (Graph, Mat, f64) {
    let g = topology.build(k, seed);
    let w = mixing_matrix(&g, scheme);
    let rho = linalg::spectral_gap(&w, seed ^ 0xA5A5);
    (g, w, rho)
}

/// Convenience: (graph, sparse weights, rho) for a named topology — the
/// fleet-scale path: never materializes a K×K matrix.
pub fn build_sparse(
    topology: Topology,
    k: usize,
    scheme: Weighting,
    seed: u64,
) -> (Graph, MixWeights, f64) {
    let g = topology.build(k, seed);
    let mw = MixWeights::from_graph(&g, scheme);
    let rho = mw.spectral_gap(seed ^ 0xA5A5);
    (g, mw, rho)
}

/// W as row-major f32, the form the XLA mix artifact consumes.
#[deprecated(
    note = "dense K*K conversion — in-process hot paths read MixWeights rows instead (DESIGN.md §8)"
)]
pub fn w_to_f32(w: &Mat) -> Vec<f32> {
    w.data.iter().map(|&x| x as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const TOPOS: &[(Topology, usize)] = &[
        (Topology::Ring, 8),
        (Topology::Chain, 8),
        (Topology::Complete, 8),
        (Topology::Star, 8),
        (Topology::Torus2d, 8),
        (Topology::Hypercube, 8),
        (Topology::ExpGraph, 8),
        (Topology::RandomRegular { degree: 3 }, 8),
    ];

    /// The fleet-scale generators at the Ks the large-K path uses.
    const SCALE_TOPOS: &[(Topology, usize)] = &[
        (Topology::Torus2d, 16),
        (Topology::Torus2d, 64),
        (Topology::ExpGraph, 16),
        (Topology::ExpGraph, 64),
        (Topology::ExpGraph, 100),
        (Topology::RandomRegular { degree: 4 }, 16),
        (Topology::RandomRegular { degree: 4 }, 64),
        (Topology::RandomRegular { degree: 3 }, 64),
    ];

    #[test]
    fn all_topologies_connected() {
        for &(t, k) in TOPOS {
            assert!(t.build(k, 1).is_connected(), "{t:?}");
        }
    }

    #[test]
    fn ring_degrees_are_two() {
        let g = Topology::Ring.build(8, 0);
        for i in 0..8 {
            assert_eq!(g.degree(i), 2);
        }
        assert_eq!(g.edge_count(), 8);
    }

    #[test]
    fn paper_ring_weights_are_one_third() {
        let g = Topology::Ring.build(8, 0);
        let w = mixing_matrix(&g, Weighting::UniformDegree);
        for i in 0..8 {
            assert!((w[(i, i)] - 1.0 / 3.0).abs() < 1e-12);
            assert!((w[(i, (i + 1) % 8)] - 1.0 / 3.0).abs() < 1e-12);
        }
    }

    #[test]
    fn all_weightings_doubly_stochastic_on_all_topologies() {
        // Property test (Assumption 1): every (topology, weighting) pair
        // yields symmetric doubly-stochastic W with entries in [0,1].
        for &(t, k) in TOPOS {
            let g = t.build(k, 3);
            for scheme in [Weighting::UniformDegree, Weighting::Metropolis, Weighting::LazyMetropolis] {
                let w = mixing_matrix(&g, scheme);
                assert!(w.is_doubly_stochastic(1e-9), "{t:?} {scheme:?}");
            }
        }
    }

    #[test]
    fn spectral_gap_ordering_matches_theory() {
        // complete > hypercube/torus > ring > chain for K=16.
        let gap = |t: Topology| build(t, 16, Weighting::UniformDegree, 5).2;
        let complete = gap(Topology::Complete);
        let hyper = gap(Topology::Hypercube);
        let ring = gap(Topology::Ring);
        let chain = gap(Topology::Chain);
        assert!(complete > hyper && hyper > ring && ring > chain,
                "complete={complete} hyper={hyper} ring={ring} chain={chain}");
        assert!((complete - 1.0).abs() < 1e-6);
        assert!(chain > 0.0);
    }

    #[test]
    fn ring8_gap_closed_form() {
        // rho = 1 - (1 + 2cos(2π/8))/3 for the 1/3-ring.
        let (_, _, rho) = build(Topology::Ring, 8, Weighting::UniformDegree, 0);
        let expect = 1.0 - (1.0 + 2.0 * (2.0 * std::f64::consts::PI / 8.0).cos()) / 3.0;
        assert!((rho - expect).abs() < 1e-6, "rho={rho} expect={expect}");
    }

    #[test]
    fn star_metropolis_handles_irregular_degrees() {
        let g = Topology::Star.build(9, 0);
        let w = mixing_matrix(&g, Weighting::Metropolis);
        assert!(w.is_doubly_stochastic(1e-9));
        // leaf-leaf weight must be zero (no edge)
        assert_eq!(w[(1, 2)], 0.0);
    }

    #[test]
    fn random_regular_is_regular_and_seeded() {
        let g1 = Topology::RandomRegular { degree: 4 }.build(16, 42);
        let g2 = Topology::RandomRegular { degree: 4 }.build(16, 42);
        for i in 0..16 {
            assert_eq!(g1.degree(i), 4);
            assert_eq!(g1.neighbors(i), g2.neighbors(i), "seeded determinism");
        }
    }

    #[test]
    fn torus_dims_reasonable() {
        assert_eq!(torus_dims(8), Some((2, 4)));
        assert_eq!(torus_dims(16), Some((4, 4)));
        assert_eq!(torus_dims(7), None);
    }

    #[test]
    fn parse_names() {
        assert_eq!(Topology::parse("ring"), Some(Topology::Ring));
        assert_eq!(Topology::parse("regular-3"), Some(Topology::RandomRegular { degree: 3 }));
        assert_eq!(Topology::parse("nope"), None);
    }

    #[test]
    fn k1_degenerates_to_identity() {
        let (_, w, rho) = build(Topology::Ring, 1, Weighting::UniformDegree, 0);
        assert_eq!(w[(0, 0)], 1.0);
        assert!((rho - 1.0).abs() < 1e-9);
    }

    #[test]
    fn mixing_preserves_mean_numerically() {
        // W x̄-preservation, the invariant behind Eq. (18).
        let (_, w, _) = build(Topology::Torus2d, 12, Weighting::Metropolis, 7);
        let x: Vec<f64> = (0..12).map(|i| (i * i) as f64).collect();
        let y = w.matvec(&x);
        let mx: f64 = x.iter().sum::<f64>() / 12.0;
        let my: f64 = y.iter().sum::<f64>() / 12.0;
        assert!((mx - my).abs() < 1e-9);
    }

    // ---- sparse MixWeights + fleet-scale generators ------------------

    const SCHEMES: [Weighting; 3] =
        [Weighting::UniformDegree, Weighting::Metropolis, Weighting::LazyMetropolis];

    #[test]
    fn prop_sparse_weights_bitwise_equal_dense_derivation() {
        // The bit-identity cornerstone: from_graph must produce EXACTLY
        // the f64 values of mixing_matrix, for every family × scheme.
        for &(t, k) in TOPOS.iter().chain(SCALE_TOPOS) {
            let g = t.build(k, 3);
            for scheme in SCHEMES {
                let dense = mixing_matrix(&g, scheme);
                let sparse = MixWeights::from_graph(&g, scheme);
                let bits = |m: &Mat| m.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
                assert_eq!(
                    bits(&sparse.to_mat()),
                    bits(&dense),
                    "{t:?} K={k} {scheme:?}: sparse derivation diverged from dense"
                );
                // And sparsifying the dense matrix is the same object.
                assert_eq!(sparse, MixWeights::from(&dense), "{t:?} K={k} {scheme:?}");
            }
        }
    }

    #[test]
    fn prop_generated_graphs_connected_and_weights_doubly_stochastic() {
        for &(t, k) in SCALE_TOPOS {
            let g = t.build(k, 11);
            assert!(g.is_connected(), "{t:?} K={k} disconnected");
            for scheme in SCHEMES {
                let mw = MixWeights::from_graph(&g, scheme);
                assert!(mw.is_doubly_stochastic(1e-9), "{t:?} K={k} {scheme:?}");
            }
        }
    }

    #[test]
    fn prop_fleet_topologies_beat_ring_spectral_gap() {
        // The point of expgraph/random-regular: far better mixing than
        // Ring at equal K.
        for k in [16usize, 64] {
            let ring = build_sparse(Topology::Ring, k, Weighting::UniformDegree, 5).2;
            for t in [Topology::ExpGraph, Topology::RandomRegular { degree: 4 }] {
                let rho = build_sparse(t, k, Weighting::UniformDegree, 5).2;
                assert!(
                    rho > 2.0 * ring,
                    "{t:?} K={k}: rho={rho} not clearly above ring's {ring}"
                );
            }
        }
    }

    #[test]
    fn sparse_and_dense_spectral_gaps_agree() {
        for &(t, k) in TOPOS {
            let rho_dense = build(t, k, Weighting::Metropolis, 5).2;
            let rho_sparse = build_sparse(t, k, Weighting::Metropolis, 5).2;
            assert!(
                (rho_dense - rho_sparse).abs() < 1e-9,
                "{t:?} K={k}: dense rho {rho_dense} vs sparse {rho_sparse}"
            );
        }
    }

    #[test]
    fn expgraph_structure() {
        // K=16: node 0 links to ±1, ±2, ±4, +8 — degree 7, log-scaling.
        let g = Topology::ExpGraph.build(16, 0);
        let mut n0 = g.neighbors(0).to_vec();
        n0.sort_unstable();
        assert_eq!(n0, vec![1, 2, 4, 8, 12, 14, 15]);
        for i in 0..16 {
            assert_eq!(g.degree(i), 7);
        }
        // K=2 degenerates to the single edge.
        assert_eq!(Topology::ExpGraph.build(2, 0).edge_count(), 1);
    }

    #[test]
    fn mixweights_rows_are_ascending_and_match_weight_lookup() {
        let g = Topology::ExpGraph.build(16, 0);
        let mw = MixWeights::from_graph(&g, Weighting::Metropolis);
        for i in 0..16 {
            let row = mw.neighbors(i);
            assert!(row.windows(2).all(|p| p[0].0 < p[1].0), "row {i} not ascending");
            let mut cur = mw.row_cursor(i);
            for j in 0..16 {
                let expect = mw.weight(i, j);
                if j != i {
                    assert_eq!(cur.weight(j), expect, "cursor({i},{j})");
                }
            }
            assert_eq!(mw.degree(i), row.len());
        }
        assert_eq!(mw.nnz(), 2 * g.edge_count());
    }

    #[test]
    fn identity_weights_mix_nothing() {
        let mw = MixWeights::identity(4);
        assert!(mw.is_doubly_stochastic(0.0));
        assert_eq!(mw.nnz(), 0);
        assert_eq!(mw.self_weight(2), 1.0);
        // lambda_2(I) = 1 => rho = 0 (disconnected).
        assert!(mw.spectral_gap(1) < 1e-9);
    }

    #[test]
    fn sparse_matvec_matches_dense() {
        let (g, w, _) = build(Topology::Star, 9, Weighting::Metropolis, 2);
        let mw = MixWeights::from_graph(&g, Weighting::Metropolis);
        let x: Vec<f64> = (0..9).map(|i| (i as f64) - 3.5).collect();
        let dense = w.matvec(&x);
        let mut sparse = vec![0.0f64; 9];
        mw.matvec_into(&x, &mut sparse);
        for (a, b) in dense.iter().zip(&sparse) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }

    #[test]
    fn parse_fleet_names() {
        assert_eq!(Topology::parse("expgraph"), Some(Topology::ExpGraph));
        assert_eq!(Topology::parse("exponential"), Some(Topology::ExpGraph));
        assert_eq!(
            Topology::parse("random-regular:4"),
            Some(Topology::RandomRegular { degree: 4 })
        );
        assert_eq!(Topology::parse("random-regular:x"), None);
        assert_eq!(Topology::parse("torus"), Some(Topology::Torus2d));
    }

    #[test]
    fn validate_rejects_infeasible_combos() {
        // Non-rectangular torus K.
        assert!(Topology::Torus2d.validate(7).is_err());
        assert!(Topology::Torus2d.validate(2).is_err());
        assert!(Topology::Torus2d.validate(12).is_ok());
        // Hypercube needs a power of two.
        assert!(Topology::Hypercube.validate(12).is_err());
        assert!(Topology::Hypercube.validate(16).is_ok());
        // Random-regular: odd K*deg, deg >= K, deg < 2.
        assert!(Topology::RandomRegular { degree: 3 }.validate(5).is_err());
        assert!(Topology::RandomRegular { degree: 8 }.validate(8).is_err());
        assert!(Topology::RandomRegular { degree: 1 }.validate(8).is_err());
        assert!(Topology::RandomRegular { degree: 4 }.validate(8).is_ok());
        // K=1 degenerates fine everywhere; K=0 never does.
        assert!(Topology::Torus2d.validate(1).is_ok());
        assert!(Topology::Ring.validate(0).is_err());
    }

    #[test]
    #[should_panic(expected = "no such factorization")]
    fn build_panics_with_the_validation_message() {
        Topology::Torus2d.build(7, 0);
    }
}
