//! End-to-end CLI tests: run the built `pdsgdm` binary as a user would.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_pdsgdm"))
}

fn run(args: &[&str]) -> (bool, String, String) {
    let out = bin().args(args).output().expect("spawn pdsgdm");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
    )
}

#[test]
fn help_lists_subcommands() {
    let (ok, stdout, _) = run(&["help"]);
    assert!(ok);
    for needle in ["train", "topology", "inspect", "algorithms"] {
        assert!(stdout.contains(needle), "missing {needle}:\n{stdout}");
    }
}

#[test]
fn algorithms_lists_all() {
    let (ok, stdout, _) = run(&["algorithms"]);
    assert!(ok);
    for name in pdsgdm::algorithms::ALL_NAMES {
        assert!(stdout.contains(name), "{name} missing");
    }
}

#[test]
fn unknown_command_fails_with_message() {
    let (ok, _, stderr) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown command"), "{stderr}");
}

#[test]
fn topology_prints_w_and_rho() {
    let (ok, stdout, _) = run(&["topology", "--kind", "ring", "--workers", "8"]);
    assert!(ok);
    assert!(stdout.contains("rho="), "{stdout}");
    assert!(stdout.contains("0.333"), "ring weights should be 1/3:\n{stdout}");
    assert!(stdout.contains("edges=8"), "{stdout}");
}

#[test]
fn topology_rejects_unknown_kind() {
    let (ok, _, stderr) = run(&["topology", "--kind", "mobius"]);
    assert!(!ok);
    assert!(stderr.contains("unknown topology"), "{stderr}");
}

#[test]
fn train_quadratic_quick_run_writes_outputs() {
    let dir = std::env::temp_dir().join(format!("pdsgdm_cli_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let csv = dir.join("trace.csv");
    let ckpt = dir.join("final.ckpt");
    let (ok, stdout, stderr) = run(&[
        "train",
        "--workload", "quadratic",
        "--algo", "pd-sgdm",
        "--workers", "4",
        "--steps", "100",
        "--period", "4",
        "--eta", "0.05",
        "--out", csv.to_str().unwrap(),
        "--ckpt", ckpt.to_str().unwrap(),
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("pd-sgdm(p=4)"), "{stdout}");
    let content = std::fs::read_to_string(&csv).unwrap();
    assert!(content.lines().count() > 2, "{content}");
    let x = pdsgdm::coordinator::load_checkpoint(&ckpt).unwrap();
    assert_eq!(x.len(), 64); // quadratic CLI workload dim
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn train_resume_reproduces_straight_run_exactly() {
    // The CI resume-smoke contract: train N steps -> checkpoint ->
    // resume to 2N must emit the *identical* trace CSV as a straight
    // 2N-step run (the checkpoint carries full state + the trace so far).
    let dir = std::env::temp_dir().join(format!("pdsgdm_cli_resume_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("half.ckpt");
    let resumed_csv = dir.join("resumed.csv");
    let straight_csv = dir.join("straight.csv");
    let base: &[&str] = &[
        "train",
        "--workload", "quadratic",
        "--algo", "pd-sgdm",
        "--workers", "4",
        "--eval-every", "20",
        "--eta", "0.05",
        "--seed", "9",
    ];

    let (ok, _, stderr) =
        run(&[base, &["--steps", "40", "--ckpt", ckpt.to_str().unwrap()][..]].concat());
    assert!(ok, "first half failed: {stderr}");
    let (ok, _, stderr) = run(&[base, &[
        "--steps", "80",
        "--resume", ckpt.to_str().unwrap(),
        "--out", resumed_csv.to_str().unwrap(),
    ][..]].concat());
    assert!(ok, "resume failed: {stderr}");
    assert!(stderr.contains("resumed at step 40"), "{stderr}");
    let (ok, _, stderr) =
        run(&[base, &["--steps", "80", "--out", straight_csv.to_str().unwrap()][..]].concat());
    assert!(ok, "straight run failed: {stderr}");

    let resumed = std::fs::read_to_string(&resumed_csv).unwrap();
    let straight = std::fs::read_to_string(&straight_csv).unwrap();
    assert!(resumed.lines().count() > 4);
    assert_eq!(resumed, straight, "resumed trace differs from uninterrupted trace");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn train_resume_rejects_mismatched_config() {
    let dir = std::env::temp_dir().join(format!("pdsgdm_cli_badresume_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("a.ckpt");
    let (ok, _, stderr) = run(&[
        "train", "--workload", "quadratic", "--algo", "pd-sgdm",
        "--workers", "4", "--steps", "20", "--ckpt", ckpt.to_str().unwrap(),
    ]);
    assert!(ok, "{stderr}");
    // different algorithm -> load must fail loudly, not silently restart
    let (ok, _, stderr) = run(&[
        "train", "--workload", "quadratic", "--algo", "d-sgd",
        "--workers", "4", "--steps", "40", "--resume", ckpt.to_str().unwrap(),
    ]);
    assert!(!ok);
    assert!(stderr.contains("algorithm"), "{stderr}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn train_comm_budget_flag_stops_early() {
    let dir = std::env::temp_dir().join(format!("pdsgdm_cli_budget_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let csv = dir.join("budget.csv");
    let (ok, stdout, stderr) = run(&[
        "train", "--workload", "quadratic", "--algo", "pd-sgdm",
        "--workers", "4", "--steps", "100000", "--eval-every", "50",
        "--comm-budget-mb", "0.01",
        "--out", csv.to_str().unwrap(),
    ]);
    assert!(ok, "{stderr}");
    assert!(stdout.contains("pd-sgdm"), "{stdout}");
    // A 100k-step run under a 0.01 MB budget must stop after a handful
    // of rounds: one K=4 ring round of the d=64 CLI quadratic moves
    // 4*2*256 = 2048 bytes, so ~6 rounds (p=4 -> ~24 steps) hit 0.01 MB.
    let content = std::fs::read_to_string(&csv).unwrap();
    let last_step: u64 = content
        .lines()
        .last()
        .and_then(|l| l.split(',').nth(1))
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad csv: {content}"));
    assert!(last_step > 0 && last_step < 1000, "budget did not stop early: {last_step}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn train_rejects_bad_flags() {
    let (ok, _, stderr) = run(&["train", "--algo", "nope"]);
    assert!(!ok);
    assert!(stderr.contains("unknown algorithm"), "{stderr}");
    let (ok2, _, stderr2) = run(&["train", "--steps"]);
    assert!(!ok2);
    assert!(stderr2.contains("needs a value"), "{stderr2}");
    let (ok3, _, stderr3) = run(&["train", "--compressor", "zip"]);
    assert!(!ok3);
    assert!(stderr3.contains("unknown compressor"), "{stderr3}");
}

#[test]
fn train_from_config_file() {
    let dir = std::env::temp_dir().join(format!("pdsgdm_cli_cfg_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("exp.toml");
    std::fs::write(
        &cfg,
        r#"
name = "cli-test"
algorithm = "cpd-sgdm"
workers = 4
steps = 60
eval_every = 20
compressor = "sign"
[workload]
kind = "quadratic"
dim = 16
[hyper]
eta = 0.02
period = 4
"#,
    )
    .unwrap();
    let (ok, stdout, stderr) = run(&["train", "--config", cfg.to_str().unwrap()]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("cpd-sgdm"), "{stdout}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn inspect_validates_artifacts_when_present() {
    if !pdsgdm::runtime::HAS_PJRT {
        eprintln!("skipping inspect test: built without the pjrt feature");
        return;
    }
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("tiny.meta.json").exists() {
        eprintln!("skipping inspect test: run `make artifacts` first");
        return;
    }
    let (ok, stdout, stderr) = run(&[
        "inspect",
        "--artifacts", artifacts.to_str().unwrap(),
        "--model", "tiny",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("compiles OK"), "{stdout}");
    assert!(stdout.contains("d=19712"), "{stdout}");
}

#[test]
fn shipped_config_files_parse_and_validate() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
    let mut n = 0;
    for entry in std::fs::read_dir(dir).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("toml") {
            continue;
        }
        let cfg = pdsgdm::config::ExperimentConfig::from_file(&path)
            .unwrap_or_else(|e| panic!("{path:?}: {e}"));
        cfg.validate().unwrap();
        n += 1;
    }
    assert!(n >= 4, "expected the shipped example configs, found {n}");
}
