//! Cross-module convergence tests: the paper's qualitative claims, each
//! checked on the pure-Rust workloads through the full coordinator path
//! (config -> SessionSpec -> Session -> run -> Trace).

use pdsgdm::algorithms::Hyper;
use pdsgdm::config::{ExperimentConfig, WorkloadConfig};
use pdsgdm::coordinator::{Session, SessionSpec};

fn run_cfg(c: ExperimentConfig) -> pdsgdm::metrics::Trace {
    let mut s = Session::build(SessionSpec::new(c)).unwrap();
    s.run_to_stop();
    s.into_trace()
}
use pdsgdm::data::Sharding;
use pdsgdm::optim::LrSchedule;
use pdsgdm::topology::Topology;

fn base_config() -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.workers = 8;
    c.steps = 600;
    c.eval_every = 100;
    c.seed = 13;
    c.workload = WorkloadConfig::Mlp { n: 1600, dim: 16, classes: 4, hidden: 24, batch: 16 };
    c.hyper = Hyper {
        lr: LrSchedule::Constant { eta: 0.1 },
        mu: 0.9,
        weight_decay: 0.0,
        period: 4,
        gamma: 0.4,
    };
    c
}

/// Paper Fig. 1 claim: PD-SGDM with p in {4,8,16} converges to ~the same
/// loss as C-SGDM (periodic communication does not hurt convergence).
#[test]
fn fig1_claim_pd_sgdm_matches_c_sgdm_loss() {
    let mut losses = Vec::new();
    for (algo, p) in [("c-sgdm", 1), ("pd-sgdm", 4), ("pd-sgdm", 8), ("pd-sgdm", 16)] {
        let mut c = base_config();
        c.algorithm = algo.into();
        c.hyper.period = p;
        let trace = run_cfg(c);
        losses.push((format!("{algo}(p={p})"), trace.final_loss()));
    }
    let c_sgdm = losses[0].1;
    for (name, l) in &losses[1..] {
        assert!(
            (l - c_sgdm).abs() < 0.25,
            "{name} final loss {l} too far from c-sgdm {c_sgdm}"
        );
    }
}

/// Paper Fig. 1(c,d) claim: final test accuracy is ~unchanged across p.
#[test]
fn fig1_claim_accuracy_insensitive_to_p() {
    let mut accs = Vec::new();
    for p in [4u64, 8, 16] {
        let mut c = base_config();
        c.hyper.period = p;
        let trace = run_cfg(c);
        accs.push(trace.final_accuracy());
    }
    let max = accs.iter().cloned().fold(f64::MIN, f64::max);
    let min = accs.iter().cloned().fold(f64::MAX, f64::min);
    assert!(max - min < 0.08, "accuracy spread too wide: {accs:?}");
    assert!(min > 0.7, "model failed to learn: {accs:?}");
}

/// Paper Fig. 2(a,b) claim: larger p reaches the same accuracy with less
/// communication.
#[test]
fn fig2_claim_larger_p_less_comm() {
    let mut rows = Vec::new();
    for p in [4u64, 8, 16] {
        let mut c = base_config();
        c.hyper.period = p;
        let trace = run_cfg(c);
        rows.push((p, trace.total_comm_mb(), trace.final_accuracy()));
    }
    assert!(rows[0].1 > 1.9 * rows[1].1, "{rows:?}");
    assert!(rows[1].1 > 1.9 * rows[2].1, "{rows:?}");
    for (_, _, acc) in &rows {
        assert!(*acc > 0.7, "{rows:?}");
    }
}

/// Paper Fig. 3 claim: CPD-SGDM (sign) converges to ~the same loss as
/// full-precision PD-SGDM while communicating far fewer bytes.
#[test]
fn fig3_claim_compression_matches_full_precision() {
    let mut c_full = base_config();
    c_full.algorithm = "pd-sgdm".into();
    c_full.hyper.period = 4;
    let full = run_cfg(c_full);

    let mut c_cpd = base_config();
    c_cpd.algorithm = "cpd-sgdm".into();
    c_cpd.hyper.period = 4;
    c_cpd.compressor = Some("sign".into());
    let cpd = run_cfg(c_cpd);

    assert!(
        (cpd.final_loss() - full.final_loss()).abs() < 0.3,
        "cpd {} vs full {}",
        cpd.final_loss(),
        full.final_loss()
    );
    assert!(
        full.total_comm_mb() / cpd.total_comm_mb() > 20.0,
        "sign should cut bytes ~32x: full {} MB vs cpd {} MB",
        full.total_comm_mb(),
        cpd.total_comm_mb()
    );
}

/// Theorem 1's σ²/K terms: with heterogeneity 0 (f* = 0) and constant η,
/// the stationary loss floor of PD-SGDM scales ~1/K — the substance of
/// the linear-speedup claim (Corollary 1). K=8's floor must be well under
/// half of K=2's.
#[test]
fn corollary1_claim_noise_floor_scales_inversely_with_k() {
    let floor = |k: usize| -> f64 {
        let mut c = base_config();
        c.workers = k;
        c.steps = 2000;
        c.eval_every = 100;
        c.workload = WorkloadConfig::Quadratic { dim: 32, heterogeneity: 0.0, noise: 2.0 };
        c.hyper.lr = LrSchedule::Constant { eta: 0.02 };
        c.hyper.period = 4;
        let trace = run_cfg(c);
        // stationary floor = mean loss over the second half of the run
        let tail: Vec<f64> = trace
            .points
            .iter()
            .filter(|p| p.step >= 1000)
            .map(|p| p.loss)
            .collect();
        tail.iter().sum::<f64>() / tail.len() as f64
    };
    let f2 = floor(2);
    let f8 = floor(8);
    assert!(
        f8 < 0.5 * f2,
        "K=8 floor {f8} should be well under half of K=2 floor {f2}"
    );
}

/// Theorem 1 claim (shape): consensus error grows with p and shrinks with
/// rho (chain vs complete).
#[test]
fn theorem1_claim_consensus_scales_with_p_and_rho() {
    let consensus = |p: u64, topo: Topology| -> f64 {
        let mut c = base_config();
        c.steps = 200;
        c.eval_every = 10;
        c.topology = topo;
        c.hyper.period = p;
        c.workload = WorkloadConfig::Quadratic { dim: 32, heterogeneity: 2.0, noise: 0.2 };
        c.hyper.lr = LrSchedule::Constant { eta: 0.02 };
        let trace = run_cfg(c);
        trace.points.iter().map(|pt| pt.consensus).fold(0.0, f64::max)
    };
    let ring_p4 = consensus(4, Topology::Ring);
    let ring_p16 = consensus(16, Topology::Ring);
    let complete_p4 = consensus(4, Topology::Complete);
    assert!(ring_p16 > ring_p4, "larger p => more drift: {ring_p16} vs {ring_p4}");
    assert!(complete_p4 < ring_p4, "larger rho => less drift: {complete_p4} vs {ring_p4}");
}

/// Non-iid robustness: PD-SGDM still learns under Dirichlet(0.3) skew.
#[test]
fn pd_sgdm_survives_non_iid_sharding() {
    let mut c = base_config();
    c.sharding = Sharding::Dirichlet { alpha: 0.3 };
    c.steps = 800;
    let trace = run_cfg(c);
    assert!(trace.final_accuracy() > 0.6, "acc {}", trace.final_accuracy());
}

/// Failure-injection: a worker whose iterate is corrupted mid-run is
/// pulled back by gossip (decentralized averaging is self-stabilizing as
/// long as subsequent gradients are sane).
#[test]
fn gossip_recovers_from_one_bad_update() {
    use pdsgdm::algorithms::{Algorithm, PdSgdm};
    use pdsgdm::comm::Network;
    use pdsgdm::grad::{GradientSource, Quadratic};
    use pdsgdm::topology::{mixing_matrix, Weighting};

    let k = 8;
    let mut src = Quadratic::new(k, 16, 1.0, 0.05, 3);
    let g = Topology::Ring.build(k, 0);
    let w = mixing_matrix(&g, Weighting::UniformDegree);
    let mut net = Network::new(&g);
    let hyper = Hyper {
        lr: LrSchedule::Constant { eta: 0.02 },
        period: 4,
        ..Hyper::default()
    };
    let mut algo = PdSgdm::new(k, src.init(1), w, hyper);
    for t in 0..200 {
        algo.step(t, &mut src, &mut net);
    }
    let healthy = src.eval(&algo.avg_params()).loss;
    // corrupt worker 3's iterate (simulates a bad batch / bit flip)
    let mut corrupted = algo.params(3).to_vec();
    for v in corrupted.iter_mut().take(8) {
        *v += 50.0;
    }
    algo.set_params_for_test(3, corrupted);
    let spiked = src.eval(&algo.avg_params()).loss;
    assert!(spiked > healthy * 2.0, "corruption should hurt: {spiked} vs {healthy}");
    // continue training; consensus + fresh gradients must re-converge
    for t in 200..1400 {
        algo.step(t, &mut src, &mut net);
    }
    let recovered = src.eval(&algo.avg_params()).loss;
    assert!(
        recovered < spiked * 0.05,
        "did not recover: healthy {healthy}, spiked {spiked}, recovered {recovered}"
    );
}

/// Regression: centralized C-SGDM's parameter-server traffic must appear
/// in the trace's comm_mb even though it bypasses the gossip Network.
#[test]
fn csgdm_comm_bytes_are_traced() {
    let mut c = base_config();
    c.algorithm = "c-sgdm".into();
    c.steps = 50;
    c.eval_every = 25;
    let trace = run_cfg(c);
    // 2 * 4 bytes * d * K per step
    assert!(trace.total_comm_mb() > 0.0);
    let d = 24 * 16 + 24 + 4 * 24 + 4; // mlp dim for base_config
    let expect = (50u64 * 2 * 4 * d as u64 * 8) as f64 / (1024.0 * 1024.0);
    assert!(
        (trace.total_comm_mb() - expect).abs() < 1e-6,
        "{} vs {expect}",
        trace.total_comm_mb()
    );
}
