//! The engine determinism contract: for EVERY algorithm in
//! `algorithms::ALL_NAMES`, driving the step loop through the persistent
//! `WorkerPool` — BOTH the local-step phase and the parallel
//! communication round (gossip mixing / compressed exchange) — must
//! produce traces **bit-identical** to the sequential path: same
//! per-worker iterates, same mean losses, same wire bytes. Randomness
//! lives in per-worker streams, every buffer is per-worker, and all
//! reductions happen on the caller's thread in worker order, so the
//! thread schedule has nothing to perturb; these tests are the
//! executable form of that argument.

use pdsgdm::algorithms::{self, Algorithm, Hyper, StepStats};
use pdsgdm::comm::Network;
use pdsgdm::engine::{ScopedTask, WorkerPool};
use pdsgdm::grad::{GradientSource, Quadratic};
use pdsgdm::optim::LrSchedule;
use pdsgdm::testing::forall;
use pdsgdm::topology::{mixing_matrix, Topology, Weighting};

/// Run `name` on `topo` for `steps` iterations on a seeded Quadratic
/// oracle; return (per-step stats, final per-worker iterates).
/// `parallel = true` forces the pooled path at tiny d — including the
/// parallel comm round, since the engine's pool is what the algorithms
/// hand to `GossipState::mix` / `CompressedExchange::round`.
fn run_algorithm(
    name: &str,
    topo: Topology,
    k: usize,
    d: usize,
    seed: u64,
    parallel: bool,
    steps: u64,
) -> (Vec<StepStats>, Vec<Vec<f32>>) {
    let mut src = Quadratic::new(k, d, 1.0, 0.1, seed);
    let graph = topo.build(k, 0);
    let w = mixing_matrix(&graph, Weighting::UniformDegree);
    let mut net = Network::new(&graph);
    let x0 = src.init(seed ^ 0xD5);
    let hyper = Hyper {
        lr: LrSchedule::Constant { eta: 0.05 },
        mu: 0.9,
        weight_decay: 1e-4,
        period: 2,
        gamma: 0.4,
    };
    let mut algo = algorithms::by_name(name, k, x0, w, hyper, None, seed)
        .unwrap_or_else(|| panic!("unknown algorithm {name}"));
    algo.set_parallel(parallel);
    let stats = (0..steps).map(|t| algo.step(t, &mut src, &mut net)).collect();
    let xs = (0..k).map(|i| algo.params(i).to_vec()).collect();
    (stats, xs)
}

fn assert_bit_identical(
    name: &str,
    topo: Topology,
    seq: &(Vec<StepStats>, Vec<Vec<f32>>),
    par: &(Vec<StepStats>, Vec<Vec<f32>>),
) {
    for (t, (s, p)) in seq.0.iter().zip(&par.0).enumerate() {
        assert_eq!(
            s.mean_loss.to_bits(),
            p.mean_loss.to_bits(),
            "{name} on {topo:?}: mean_loss diverged at step {t} ({} vs {})",
            s.mean_loss,
            p.mean_loss
        );
        assert_eq!(s.bytes, p.bytes, "{name} on {topo:?}: wire bytes diverged at step {t}");
        assert_eq!(
            s.communicated, p.communicated,
            "{name} on {topo:?}: schedule diverged at step {t}"
        );
    }
    for (w, (a, b)) in seq.1.iter().zip(&par.1).enumerate() {
        assert_eq!(a.len(), b.len(), "{name} on {topo:?}: worker {w} dimension mismatch");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{name} on {topo:?}: worker {w} coord {i} diverged ({x} vs {y})"
            );
        }
    }
}

#[test]
fn prop_parallel_engine_is_bit_identical_for_every_algorithm() {
    forall(0xE9619E, 6, |rng| {
        let k = 3 + rng.below(6); // 3..=8 workers
        let d = 1 + rng.below(48);
        let seed = rng.next_u64();
        for name in algorithms::ALL_NAMES {
            let seq = run_algorithm(name, Topology::Ring, k, d, seed, false, 9);
            let par = run_algorithm(name, Topology::Ring, k, d, seed, true, 9);
            assert_bit_identical(name, Topology::Ring, &seq, &par);
        }
    });
}

#[test]
fn prop_parallel_comm_round_is_bit_identical_across_topologies() {
    // ALL_NAMES × {Ring, Star, Chain}: the pooled comm round
    // (force-enabled at tiny d via set_parallel) must match the
    // sequential round bit-for-bit on regular AND irregular graphs —
    // the star's hub mixes K−1 neighbor terms, the chain's endpoints
    // only one, so this sweeps every weighted-sum arity the fan-out
    // can produce. period=2 over 8 steps → 4 comm rounds each.
    forall(0x70B0107, 3, |rng| {
        let k = 3 + rng.below(6);
        let d = 1 + rng.below(32);
        let seed = rng.next_u64();
        for topo in [Topology::Ring, Topology::Star, Topology::Chain] {
            for name in algorithms::ALL_NAMES {
                let seq = run_algorithm(name, topo, k, d, seed, false, 8);
                let par = run_algorithm(name, topo, k, d, seed, true, 8);
                assert_bit_identical(name, topo, &seq, &par);
            }
        }
    });
}

#[test]
fn parallel_engine_is_bit_identical_on_split_oracles() {
    // The Mlp and Logistic oracles split into per-worker shards too;
    // spot-check the paper's primary algorithm on both.
    use pdsgdm::data::{Blobs, Sharding};
    use pdsgdm::grad::{Logistic, Mlp};

    fn run(parallel: bool, mlp: bool) -> (Vec<f64>, Vec<Vec<f32>>) {
        let k = 4;
        let data = Blobs { n: 240, dim: 8, classes: 3, spread: 3.0 }.generate(99);
        let mut src: Box<dyn GradientSource> = if mlp {
            Box::new(Mlp::new(data, k, Sharding::Iid, 12, 16, 0.1, 5))
        } else {
            Box::new(Logistic::new(data, k, Sharding::Iid, 16, 1e-3, 5))
        };
        let graph = Topology::Ring.build(k, 0);
        let w = mixing_matrix(&graph, Weighting::UniformDegree);
        let mut net = Network::new(&graph);
        let x0 = src.init(3);
        let mut algo = algorithms::by_name("pd-sgdm", k, x0, w, Hyper::default(), None, 5).unwrap();
        algo.set_parallel(parallel);
        let losses = (0..12).map(|t| algo.step(t, src.as_mut(), &mut net).mean_loss).collect();
        let xs = (0..k).map(|i| algo.params(i).to_vec()).collect();
        (losses, xs)
    }

    for mlp in [false, true] {
        let (l_seq, x_seq) = run(false, mlp);
        let (l_par, x_par) = run(true, mlp);
        assert!(
            l_seq.iter().zip(&l_par).all(|(a, b)| a.to_bits() == b.to_bits()),
            "mlp={mlp}: losses diverged"
        );
        let bitwise = x_seq.iter().zip(&x_par).all(|(a, b)| {
            a.len() == b.len() && a.iter().zip(b).all(|(p, q)| p.to_bits() == q.to_bits())
        });
        assert!(bitwise, "mlp={mlp}: iterates diverged");
    }
}

// ---------------------------------------------------------------------------
// WorkerPool unit behavior (public API)
// ---------------------------------------------------------------------------

#[test]
fn worker_pool_join_order_is_deterministic() {
    // Results must come back in TASK order no matter which thread
    // finishes first — we skew completion so late tasks finish early.
    let pool = WorkerPool::new(4);
    for round in 0..25u64 {
        let tasks: Vec<ScopedTask<'_, u64>> = (0..11u64)
            .map(|i| {
                Box::new(move || {
                    if (i + round) % 3 == 0 {
                        std::thread::sleep(std::time::Duration::from_micros(300));
                    }
                    i * 7 + round
                }) as ScopedTask<'_, u64>
            })
            .collect();
        let got = pool.run_scoped(tasks);
        assert_eq!(got, (0..11).map(|i| i * 7 + round).collect::<Vec<_>>());
    }
}

#[test]
fn worker_pool_shutdown_on_drop_is_clean() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    let counter = Arc::new(AtomicUsize::new(0));
    let pool = WorkerPool::new(3);
    assert_eq!(pool.threads(), 3);
    let tasks: Vec<ScopedTask<'_, ()>> = (0..30)
        .map(|_| {
            let c = Arc::clone(&counter);
            Box::new(move || {
                c.fetch_add(1, Ordering::SeqCst);
            }) as ScopedTask<'_, ()>
        })
        .collect();
    pool.run_scoped(tasks);
    assert_eq!(counter.load(Ordering::SeqCst), 30, "every task ran exactly once");
    // Drop joins every thread; if shutdown leaked a parked thread this
    // would deadlock the test binary (harness timeout), and if any task
    // closure were still alive it would hold a counter reference.
    drop(pool);
    assert_eq!(Arc::strong_count(&counter), 1, "all task closures were consumed");
}
