//! The engine determinism contract: for EVERY algorithm in
//! `algorithms::ALL_NAMES`, driving the local-step phase through the
//! parallel `LocalStepEngine` must produce traces **bit-identical** to
//! the sequential path — same per-worker iterates, same mean losses,
//! same wire bytes. Randomness lives in per-worker streams and every
//! buffer is per-worker, so the thread schedule has nothing to perturb;
//! this test is the executable form of that argument.

use pdsgdm::algorithms::{self, Algorithm, Hyper, StepStats};
use pdsgdm::comm::Network;
use pdsgdm::grad::{GradientSource, Quadratic};
use pdsgdm::optim::LrSchedule;
use pdsgdm::testing::forall;
use pdsgdm::topology::{mixing_matrix, Topology, Weighting};

/// Run `name` for `steps` iterations on a seeded Quadratic oracle;
/// return (per-step stats, final per-worker iterates).
fn run_algorithm(
    name: &str,
    k: usize,
    d: usize,
    seed: u64,
    parallel: bool,
    steps: u64,
) -> (Vec<StepStats>, Vec<Vec<f32>>) {
    let mut src = Quadratic::new(k, d, 1.0, 0.1, seed);
    let graph = Topology::Ring.build(k, 0);
    let w = mixing_matrix(&graph, Weighting::UniformDegree);
    let mut net = Network::new(&graph);
    let x0 = src.init(seed ^ 0xD5);
    let hyper = Hyper {
        lr: LrSchedule::Constant { eta: 0.05 },
        mu: 0.9,
        weight_decay: 1e-4,
        period: 2,
        gamma: 0.4,
    };
    let mut algo = algorithms::by_name(name, k, x0, w, hyper, None, seed)
        .unwrap_or_else(|| panic!("unknown algorithm {name}"));
    algo.set_parallel(parallel);
    let stats = (0..steps).map(|t| algo.step(t, &mut src, &mut net)).collect();
    let xs = (0..k).map(|i| algo.params(i).to_vec()).collect();
    (stats, xs)
}

fn assert_bit_identical(name: &str, seq: &(Vec<StepStats>, Vec<Vec<f32>>), par: &(Vec<StepStats>, Vec<Vec<f32>>)) {
    for (t, (s, p)) in seq.0.iter().zip(&par.0).enumerate() {
        assert_eq!(
            s.mean_loss.to_bits(),
            p.mean_loss.to_bits(),
            "{name}: mean_loss diverged at step {t} ({} vs {})",
            s.mean_loss,
            p.mean_loss
        );
        assert_eq!(s.bytes, p.bytes, "{name}: wire bytes diverged at step {t}");
        assert_eq!(s.communicated, p.communicated, "{name}: schedule diverged at step {t}");
    }
    for (w, (a, b)) in seq.1.iter().zip(&par.1).enumerate() {
        assert_eq!(a.len(), b.len(), "{name}: worker {w} dimension mismatch");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{name}: worker {w} coord {i} diverged ({x} vs {y})"
            );
        }
    }
}

#[test]
fn prop_parallel_engine_is_bit_identical_for_every_algorithm() {
    forall(0xE9619E, 6, |rng| {
        let k = 3 + rng.below(6); // 3..=8 workers
        let d = 1 + rng.below(48);
        let seed = rng.next_u64();
        for name in algorithms::ALL_NAMES {
            let seq = run_algorithm(name, k, d, seed, false, 9);
            let par = run_algorithm(name, k, d, seed, true, 9);
            assert_bit_identical(name, &seq, &par);
        }
    });
}

#[test]
fn parallel_engine_is_bit_identical_on_split_oracles() {
    // The Mlp and Logistic oracles split into per-worker shards too;
    // spot-check the paper's primary algorithm on both.
    use pdsgdm::data::{Blobs, Sharding};
    use pdsgdm::grad::{Logistic, Mlp};

    fn run(parallel: bool, mlp: bool) -> (Vec<f64>, Vec<Vec<f32>>) {
        let k = 4;
        let data = Blobs { n: 240, dim: 8, classes: 3, spread: 3.0 }.generate(99);
        let mut src: Box<dyn GradientSource> = if mlp {
            Box::new(Mlp::new(data, k, Sharding::Iid, 12, 16, 0.1, 5))
        } else {
            Box::new(Logistic::new(data, k, Sharding::Iid, 16, 1e-3, 5))
        };
        let graph = Topology::Ring.build(k, 0);
        let w = mixing_matrix(&graph, Weighting::UniformDegree);
        let mut net = Network::new(&graph);
        let x0 = src.init(3);
        let mut algo = algorithms::by_name("pd-sgdm", k, x0, w, Hyper::default(), None, 5).unwrap();
        algo.set_parallel(parallel);
        let losses = (0..12).map(|t| algo.step(t, src.as_mut(), &mut net).mean_loss).collect();
        let xs = (0..k).map(|i| algo.params(i).to_vec()).collect();
        (losses, xs)
    }

    for mlp in [false, true] {
        let (l_seq, x_seq) = run(false, mlp);
        let (l_par, x_par) = run(true, mlp);
        assert!(
            l_seq.iter().zip(&l_par).all(|(a, b)| a.to_bits() == b.to_bits()),
            "mlp={mlp}: losses diverged"
        );
        let bitwise = x_seq.iter().zip(&x_par).all(|(a, b)| {
            a.len() == b.len() && a.iter().zip(b).all(|(p, q)| p.to_bits() == q.to_bits())
        });
        assert!(bitwise, "mlp={mlp}: iterates diverged");
    }
}
