//! Acceptance tests for the fault-injection & heterogeneity layer:
//!
//! * a `FaultPlan` with **all rates zero** is bit-identical to a
//!   faultless run — for every algorithm in `ALL_NAMES` × {Ring, Star,
//!   Chain} (the layer's central contract: installing the plan must not
//!   perturb a single bit, so the hardened recv paths and the zero-rate
//!   plan are property-tested against the legacy execution path);
//! * a churn run (leave → departure checkpoint → rejoin-and-restore)
//!   replays its trace bit-identically with the same fault seed;
//! * a faulty run interrupted mid-absence — with in-flight delayed
//!   messages and a stashed departure checkpoint — resumes from its
//!   `PDSGDM02` checkpoint bit-identically (fault RNG, delay buffer,
//!   absence flags, and churn stashes all round-trip);
//! * a drop-heavy unreliable fabric still completes with finite loss
//!   (renormalized mixing never divides by a vanished neighborhood);
//! * lossy **compressed** links (`faults.compressed = true`): the
//!   per-receiver x̂-replica path is bit-identical to the canonical
//!   single-x̂ path at zero rates, converges finitely under 50% encoded
//!   drops, and resumes byte-identically from a mid-run checkpoint with
//!   replica arenas and in-flight encoded messages in the file.

use pdsgdm::algorithms::{Algorithm as _, ALL_NAMES};
use pdsgdm::config::{ChurnEvent, ExperimentConfig, WorkloadConfig};
use pdsgdm::coordinator::{Session, SessionSpec, StopCondition};
use pdsgdm::metrics::Trace;
use pdsgdm::topology::Topology;

fn base_config(algorithm: &str, topology: Topology) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.algorithm = algorithm.into();
    c.workers = 4;
    c.steps = 60;
    c.eval_every = 10;
    c.seed = 77;
    c.topology = topology;
    // noise > 0 so every trace bit depends on the RNG streams.
    c.workload = WorkloadConfig::Quadratic { dim: 16, heterogeneity: 1.0, noise: 0.2 };
    c.hyper.lr = pdsgdm::optim::LrSchedule::Constant { eta: 0.02 };
    c
}

/// A config whose fault layer is *installed but inert*: `enabled = true`
/// forces the zero-rate `FaultPlan` onto the network.
fn zero_rate_faults(mut c: ExperimentConfig) -> ExperimentConfig {
    c.faults.enabled = true;
    c
}

/// Drop + delay + reorder + one worker leaving and rejoining.
fn full_faults(mut c: ExperimentConfig) -> ExperimentConfig {
    c.faults.drop_prob = 0.15;
    c.faults.delay_prob = 0.15;
    c.faults.max_delay = 2;
    c.faults.reorder_prob = 0.25;
    c.faults.seed = 9;
    c.faults.churn = vec![ChurnEvent { worker: 1, leave_step: 10, rejoin_step: 40 }];
    c
}

fn run_to_end(cfg: ExperimentConfig) -> Session<'static> {
    let mut s = Session::build(SessionSpec::new(cfg)).unwrap();
    s.run_to_stop();
    s
}

fn assert_traces_bit_identical(name: &str, a: &Trace, b: &Trace) {
    assert_eq!(a.points.len(), b.points.len(), "{name}: point counts differ");
    for (pa, pb) in a.points.iter().zip(&b.points) {
        assert_eq!(pa.step, pb.step, "{name}");
        let t = pa.step;
        assert_eq!(pa.loss.to_bits(), pb.loss.to_bits(), "{name}: loss @ step {t}");
        assert_eq!(pa.comm_mb.to_bits(), pb.comm_mb.to_bits(), "{name}: comm_mb @ {t}");
        assert_eq!(
            pa.consensus.to_bits(),
            pb.consensus.to_bits(),
            "{name}: consensus @ {t}"
        );
        assert_eq!(
            pa.sim_seconds.to_bits(),
            pb.sim_seconds.to_bits(),
            "{name}: sim_seconds @ {t}"
        );
    }
}

fn assert_params_bit_identical(name: &str, a: &Session, b: &Session) {
    let (a, b) = (a.algo(), b.algo());
    let bits = |x: &[f32]| x.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
    for k in 0..a.k() {
        assert_eq!(bits(a.params(k)), bits(b.params(k)), "{name}: worker {k} iterate");
    }
}

#[test]
fn zero_rate_fault_plan_is_bit_identical_for_every_algorithm_and_topology() {
    for topology in [Topology::Ring, Topology::Star, Topology::Chain, Topology::ExpGraph] {
        for name in ALL_NAMES {
            let label = format!("{name} on {topology:?}");
            let plain = run_to_end(base_config(name, topology));
            let faulted = run_to_end(zero_rate_faults(base_config(name, topology)));
            assert_traces_bit_identical(&label, plain.trace(), faulted.trace());
            assert_params_bit_identical(&label, &plain, &faulted);
            assert_eq!(plain.comm_bytes(), faulted.comm_bytes(), "{label}: bytes");
        }
    }
}

#[test]
fn churn_run_replays_bit_identically_with_same_fault_seed() {
    for name in ["pd-sgdm", "cpd-sgdm", "momentum-tracking"] {
        let cfg = full_faults(base_config(name, Topology::Ring));
        let a = run_to_end(cfg.clone());
        let b = run_to_end(cfg);
        let label = format!("{name} churn replay");
        assert_traces_bit_identical(&label, a.trace(), b.trace());
        assert_params_bit_identical(&label, &a, &b);
    }
}

#[test]
fn faulty_run_resumes_bit_identically_from_mid_absence_checkpoint() {
    // Interrupt at step 30: worker 1 is absent (left at 10, rejoins at
    // 40), a departure checkpoint is stashed, and with delay_prob > 0
    // the plan likely holds in-flight messages — all of it must survive
    // the checkpoint round-trip for the resumed trace to match.
    let cfg = full_faults(base_config("pd-sgdm", Topology::Ring));

    let mut straight = Session::build(SessionSpec::new(cfg.clone())).unwrap();
    straight.run_until(StopCondition::Steps(60));

    let mut first = Session::build(SessionSpec::new(cfg.clone())).unwrap();
    first.run_until(StopCondition::Steps(30));
    let ckpt = first.save_state();
    drop(first);

    let mut resumed = Session::build(SessionSpec::new(cfg)).unwrap();
    resumed.load_state(&ckpt).unwrap();
    assert_eq!(resumed.steps_done(), 30);
    resumed.run_until(StopCondition::Steps(60));

    assert_traces_bit_identical("pd-sgdm faulty resume", straight.trace(), resumed.trace());
    assert_params_bit_identical("pd-sgdm faulty resume", &straight, &resumed);
}

#[test]
fn faulty_checkpoint_rejected_by_faultless_session() {
    let mut s = run_to_end(full_faults(base_config("pd-sgdm", Topology::Ring)));
    let ckpt = s.save_state();
    let mut plain = Session::build(SessionSpec::new(base_config(
        "pd-sgdm",
        Topology::Ring,
    )))
    .unwrap();
    let err = plain.load_state(&ckpt).unwrap_err();
    assert!(err.contains("config") || err.contains("fault"), "{err}");
    s.run_until(StopCondition::Steps(60)); // still drivable after save
}

/// The algorithms whose gossip is compressed (`Payload::Encoded`) and
/// which therefore hold per-receiver x̂ replicas under lossy links.
const COMPRESSED_ALGOS: [&str; 3] = ["cpd-sgdm", "choco-sgd", "deepsqueeze"];

#[test]
fn zero_rate_compressed_plan_is_bit_identical_on_every_topology() {
    // The per-receiver replica machinery turns on with `compressed =
    // true`, so a zero-rate compressed plan runs the replica code path
    // end to end — and must still reproduce the canonical single-x̂ run
    // bit for bit (every receiver hears every neighbor, every replica
    // stays equal to the sender's own x̂, and the renormalization never
    // engages). K=4 ExpGraph is the complete graph, so the three
    // topologies cover degree-2 rings, the star's hub/leaf asymmetry,
    // and an all-to-all neighborhood.
    for topology in [Topology::Ring, Topology::Star, Topology::ExpGraph] {
        for name in COMPRESSED_ALGOS {
            let label = format!("{name} on {topology:?} (compressed zero-rate)");
            let plain = run_to_end(base_config(name, topology));
            let mut cfg = zero_rate_faults(base_config(name, topology));
            cfg.faults.compressed = true;
            let faulted = run_to_end(cfg);
            assert_traces_bit_identical(&label, plain.trace(), faulted.trace());
            assert_params_bit_identical(&label, &plain, &faulted);
            assert_eq!(plain.comm_bytes(), faulted.comm_bytes(), "{label}: bytes");
        }
    }
}

#[test]
fn drop_heavy_compressed_links_still_converge_finitely() {
    for topology in [Topology::Ring, Topology::ExpGraph] {
        for name in COMPRESSED_ALGOS {
            let mut c = base_config(name, topology);
            c.faults.drop_prob = 0.5;
            c.faults.seed = 4;
            c.faults.compressed = true;
            let s = run_to_end(c);
            let label = format!("{name} on {topology:?}");
            assert!(s.trace().final_loss().is_finite(), "{label}");
            assert!(
                s.trace().final_loss() < s.trace().points[0].loss,
                "{label}: no progress under 50% compressed drops"
            );
        }
    }
}

#[test]
fn compressed_faulty_run_resumes_bit_identically_from_mid_run_checkpoint() {
    // Interrupt at step 30 under compressed drops + delays: the
    // checkpoint carries the per-receiver replica arenas (the new
    // "hat-replicas" section), the fault RNG mid-stream, and possibly
    // in-flight delayed *encoded* messages — all must survive the
    // round-trip for the resumed run to match the straight run, and the
    // two final checkpoints must be byte-identical.
    for name in COMPRESSED_ALGOS {
        let mut cfg = base_config(name, Topology::Ring);
        cfg.faults.drop_prob = 0.3;
        cfg.faults.delay_prob = 0.2;
        cfg.faults.max_delay = 2;
        cfg.faults.seed = 21;
        cfg.faults.compressed = true;

        let mut straight = Session::build(SessionSpec::new(cfg.clone())).unwrap();
        straight.run_until(StopCondition::Steps(60));

        let mut first = Session::build(SessionSpec::new(cfg.clone())).unwrap();
        first.run_until(StopCondition::Steps(30));
        let ckpt = first.save_state();
        drop(first);

        let mut resumed = Session::build(SessionSpec::new(cfg)).unwrap();
        resumed.load_state(&ckpt).unwrap();
        assert_eq!(resumed.steps_done(), 30);
        resumed.run_until(StopCondition::Steps(60));

        let label = format!("{name} compressed faulty resume");
        assert_traces_bit_identical(&label, straight.trace(), resumed.trace());
        assert_params_bit_identical(&label, &straight, &resumed);
        assert_eq!(
            straight.save_state(),
            resumed.save_state(),
            "{label}: final checkpoints must be byte-identical"
        );
    }
}

#[test]
fn drop_heavy_fabric_still_converges_finitely() {
    for name in ["pd-sgdm", "d-sgd", "momentum-tracking"] {
        let mut c = base_config(name, Topology::Ring);
        c.faults.drop_prob = 0.5;
        c.faults.seed = 4;
        let s = run_to_end(c);
        assert!(s.trace().final_loss().is_finite(), "{name}");
        assert!(
            s.trace().final_loss() < s.trace().points[0].loss,
            "{name}: no progress under 50% drops"
        );
    }
}
