//! Integration tests over the real AOT artifacts: load HLO text, compile
//! on the PJRT CPU client, execute, and cross-check the numerics against
//! the pure-Rust implementations of the same math.
//!
//! Requires `make artifacts` (tiny config) AND a build with the `pjrt`
//! feature. If either is missing the tests skip with a message instead
//! of failing, so `cargo test` stays green in a fresh checkout and in
//! the offline (stub-runtime) build; CI with the xla dependency builds
//! artifacts first.

use pdsgdm::algorithms::Algorithm;
use pdsgdm::grad::GradientSource;
use pdsgdm::linalg;
use pdsgdm::rng::Xoshiro256;
use pdsgdm::runtime::Runtime;
use pdsgdm::topology::{mixing_matrix, Topology, Weighting};

fn runtime() -> Option<Runtime> {
    if !pdsgdm::runtime::HAS_PJRT {
        eprintln!("skipping runtime integration test: built without the pjrt feature");
        return None;
    }
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("tiny.meta.json").exists() {
        eprintln!("skipping runtime integration test: run `make artifacts` first");
        return None;
    }
    Some(Runtime::new(dir).expect("runtime"))
}

#[test]
fn train_step_executes_and_loss_is_log_vocab() {
    let Some(rt) = runtime() else { return };
    let step = rt.train_step("tiny").expect("compile train_step");
    let m = step.manifest.clone();
    let params = m.init_params(1);
    let mut rng = Xoshiro256::seed_from_u64(2);
    let tokens: Vec<i32> = (0..m.batch * (m.seq_len + 1))
        .map(|_| rng.below(m.vocab) as i32)
        .collect();
    let (loss, grad) = step.run(&params, &tokens).expect("execute");
    // random init + uniform tokens => loss ~ ln(V)
    let expect = (m.vocab as f64).ln();
    assert!(
        (loss as f64 - expect).abs() < 0.7,
        "loss {loss} vs ln(V) {expect}"
    );
    assert_eq!(grad.len(), m.d);
    assert!(grad.iter().all(|g| g.is_finite()));
    assert!(linalg::norm(&grad) > 1e-6, "gradient must be nonzero");
}

#[test]
fn train_step_gradient_descends() {
    // A few steps of plain GD on one fixed batch must reduce the loss —
    // proves the grad output of the fused fwd+bwd HLO is a real gradient.
    let Some(rt) = runtime() else { return };
    let step = rt.train_step("tiny").expect("compile");
    let m = step.manifest.clone();
    let mut params = m.init_params(3);
    let mut rng = Xoshiro256::seed_from_u64(4);
    let tokens: Vec<i32> = (0..m.batch * (m.seq_len + 1))
        .map(|_| rng.below(m.vocab) as i32)
        .collect();
    let (loss0, _) = step.run(&params, &tokens).expect("exec");
    for _ in 0..5 {
        let (_, grad) = step.run(&params, &tokens).expect("exec");
        linalg::axpy(-0.5, &grad, &mut params);
    }
    let (loss1, _) = step.run(&params, &tokens).expect("exec");
    assert!(loss1 < loss0, "GD failed: {loss0} -> {loss1}");
}

#[test]
fn momentum_artifact_matches_rust_optimizer() {
    // The L1 Pallas momentum kernel (via XLA) and optim::MomentumState
    // must compute identical math (weight_decay=0 path).
    let Some(rt) = runtime() else { return };
    let mstep = rt.momentum_step("tiny").expect("compile momentum");
    let d = mstep.d;
    let mut rng = Xoshiro256::seed_from_u64(5);
    let x = rng.normal_vec(d, 1.0);
    let m = rng.normal_vec(d, 0.5);
    let g = rng.normal_vec(d, 2.0);
    let (eta, mu) = (0.07f32, 0.9f32);

    let (x_xla, m_xla) = mstep.run(&x, &m, &g, eta, mu).expect("exec");

    let mut st = pdsgdm::optim::MomentumState::new(d, mu, 0.0);
    st.m = m.clone();
    let mut x_rust = x.clone();
    st.step(&mut x_rust, &g, eta);

    pdsgdm::testing::assert_allclose(&x_xla, &x_rust, 1e-5, 1e-6);
    pdsgdm::testing::assert_allclose(&m_xla, &st.m, 1e-5, 1e-6);
}

#[test]
fn mix_artifact_matches_rust_gossip() {
    // The L1 Pallas mix kernel result == W @ X computed in Rust, and it
    // preserves the worker average (Assumption 1 invariant).
    let Some(rt) = runtime() else { return };
    let k = 8;
    let mix = rt.mix_step("tiny", k).expect("compile mix");
    let d = mix.d;
    let g = Topology::Ring.build(k, 0);
    let w = mixing_matrix(&g, Weighting::UniformDegree);
    // The XLA mix kernel genuinely wants the dense K×K f32 table — the
    // one consumer the sparse-CSR migration deliberately left dense.
    #[allow(deprecated)]
    let wf = pdsgdm::topology::w_to_f32(&w);
    let mut rng = Xoshiro256::seed_from_u64(6);
    let xs_rows: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(d, 1.0)).collect();
    let xs_flat: Vec<f32> = xs_rows.iter().flatten().copied().collect();

    let out = mix.run(&wf, &xs_flat).expect("exec");
    assert_eq!(out.len(), k * d);

    for i in 0..k {
        let mut want = vec![0.0f32; d];
        for j in 0..k {
            linalg::axpy(w[(i, j)] as f32, &xs_rows[j], &mut want);
        }
        pdsgdm::testing::assert_allclose(&out[i * d..(i + 1) * d], &want, 1e-4, 1e-5);
    }
    // average preservation
    let before = linalg::mean_of(&xs_rows);
    let after_rows: Vec<Vec<f32>> = (0..k).map(|i| out[i * d..(i + 1) * d].to_vec()).collect();
    let after = linalg::mean_of(&after_rows);
    pdsgdm::testing::assert_allclose(&after, &before, 1e-4, 1e-4);
}

#[test]
fn mix_step_rejects_unknown_k() {
    let Some(rt) = runtime() else { return };
    let err = match rt.mix_step("tiny", 7) {
        Ok(_) => panic!("K=7 has no artifact"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("K=7"), "{err}");
}

#[test]
fn xla_grad_source_trains_pd_sgdm_end_to_end() {
    // The full L3-over-L2-over-L1 stack on the tiny model: 8 workers,
    // ring topology, PD-SGDM p=4, Markov corpus. Loss must drop well
    // below the random-init ln(V) baseline within ~120 steps.
    let Some(rt) = runtime() else { return };
    let step = rt.train_step("tiny").expect("compile");
    let vocab = step.manifest.vocab;
    let k = 8;
    let corpus = (step.manifest.seq_len + 1) * 64 * k;
    let mut src =
        pdsgdm::runtime::XlaGradSource::new(step, k, corpus, 7).expect("grad source");
    let x0 = src.init(7);

    let (graph, w, _rho) = pdsgdm::topology::build(
        Topology::Ring,
        k,
        Weighting::UniformDegree,
        0,
    );
    let mut net = pdsgdm::comm::Network::new(&graph);
    let hyper = pdsgdm::algorithms::Hyper {
        lr: pdsgdm::optim::LrSchedule::Constant { eta: 0.25 },
        mu: 0.9,
        weight_decay: 0.0,
        period: 4,
        gamma: 0.4,
    };
    let mut algo = pdsgdm::algorithms::PdSgdm::new(k, x0, w, hyper);

    let before = src.eval(&algo.avg_params()).loss;
    for t in 0..120 {
        algo.step(t, &mut src, &mut net);
    }
    let after = src.eval(&algo.avg_params()).loss;
    let baseline = (vocab as f64).ln();
    assert!(
        after < before && after < baseline - 0.5,
        "e2e training failed: {before} -> {after} (ln V = {baseline})"
    );
    // communication really happened and was metered
    assert!(net.total_bytes > 0);
    assert_eq!(net.rounds, 120 / 4);
}
