//! Integration tests for the training service: concurrent jobs on one
//! shared pool, the Prometheus `/metrics` + JSON `/jobs` endpoints over
//! real TCP, and checkpoint-loading robustness (truncation/garbage
//! fuzz) backing the daemon's drain/resume path.

use std::path::PathBuf;
use std::time::Duration;

use pdsgdm::config::{ExperimentConfig, ServeConfig};
use pdsgdm::coordinator::{Session, SessionSpec, StopCondition};
use pdsgdm::json::Json;
use pdsgdm::service::metrics_export::validate_exposition;
use pdsgdm::service::queue::JobState;
use pdsgdm::service::{http, Daemon};

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "pdsgdm_svc_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A quadratic job big enough to still be running when the test
/// scrapes mid-flight (tens of thousands of cheap steps).
fn job_toml(name: &str, steps: u64) -> String {
    format!(
        "algorithm = \"pd-sgdm\"\nworkers = 4\nsteps = {steps}\neval_every = 2000\n\
         [workload]\nkind = \"quadratic\"\ndim = 16\nheterogeneity = 1.0\nnoise = 0.05\n\
         [hyper]\neta = 0.05\n\
         [job]\nname = \"{name}\"\n"
    )
}

/// Extract the value of an exposition sample line by its exact prefix,
/// e.g. `pdsgdm_job_steps_total{job="svc-a"}`.
fn sample(text: &str, prefix: &str) -> Option<f64> {
    text.lines()
        .find_map(|l| l.strip_prefix(prefix).map(|v| v.trim().parse().expect("numeric sample")))
}

#[test]
fn concurrent_jobs_export_valid_monotone_metrics_over_http() {
    let state = temp_dir("metrics");
    let daemon = Daemon::new(ServeConfig {
        listen: "127.0.0.1:0".into(),
        max_concurrent: 2,
        pool_threads: Some(2),
        state_dir: state.display().to_string(),
        spool_dir: None,
        poll_ms: 10,
        exit_when_idle: true,
    })
    .unwrap();
    const STEPS: u64 = 40_000;
    daemon.submit_toml(&job_toml("svc-a", STEPS)).unwrap();
    daemon.submit_toml(&job_toml("svc-b", STEPS)).unwrap();

    let steps_line = |job: &str| format!("pdsgdm_job_steps_total{{job=\"{job}\"}}");
    let (scrape1, scrape2) = std::thread::scope(|scope| {
        let handle = scope.spawn(|| daemon.run().unwrap());
        let addr = loop {
            if let Some(a) = daemon.http_addr() {
                break a;
            }
            std::thread::sleep(Duration::from_millis(2));
        };
        // Wait until both runners picked up their job and stepped.
        while daemon.registry().steps_total("svc-a") == 0
            || daemon.registry().steps_total("svc-b") == 0
        {
            std::thread::sleep(Duration::from_millis(2));
        }
        let (status, scrape1) = http::get(addr, "/metrics").unwrap();
        assert_eq!(status, 200);
        std::thread::sleep(Duration::from_millis(20));
        let (_, scrape2) = http::get(addr, "/metrics").unwrap();

        // The JSON endpoint serves the queue snapshot mid-run too.
        let (status, jobs) = http::get(addr, "/jobs").unwrap();
        assert_eq!(status, 200);
        let doc = Json::parse(&jobs).unwrap();
        assert_eq!(doc.get("jobs").and_then(|j| j.as_arr()).unwrap().len(), 2);

        // Unknown routes 404 without killing the daemon.
        let (status, _) = http::get(addr, "/nope").unwrap();
        assert_eq!(status, 404);

        handle.join().unwrap();
        (scrape1, scrape2)
    });

    // Both scrapes are well-formed exposition text with unique families.
    validate_exposition(&scrape1).unwrap();
    validate_exposition(&scrape2).unwrap();
    for text in [&scrape1, &scrape2] {
        assert!(text.contains("# TYPE pdsgdm_job_steps_total counter"), "{text}");
        assert!(text.contains("pdsgdm_daemon_up 1"), "{text}");
    }
    // Counters are monotone between scrapes, for both concurrent jobs.
    for job in ["svc-a", "svc-b"] {
        let a = sample(&scrape1, &steps_line(job)).unwrap();
        let b = sample(&scrape2, &steps_line(job)).unwrap();
        assert!(a >= 1.0, "{job} stepped before scrape 1");
        assert!(b >= a, "{job} steps_total went backwards: {a} -> {b}");
        assert!(b <= STEPS as f64);
    }

    // After the daemon exits, everything completed and the final
    // registry state reflects the full run.
    let snap = daemon.queue().snapshot();
    assert!(snap.iter().all(|j| j.state == JobState::Completed), "{snap:?}");
    let final_text = daemon.registry().render();
    validate_exposition(&final_text).unwrap();
    for job in ["svc-a", "svc-b"] {
        assert_eq!(sample(&final_text, &steps_line(job)), Some(STEPS as f64));
        assert!(sample(&final_text, &format!("pdsgdm_job_last_loss{{job=\"{job}\"}}")).is_some());
        assert!(
            sample(&final_text, &format!("pdsgdm_job_wire_bytes_total{{job=\"{job}\"}}"))
                .unwrap()
                > 0.0
        );
    }
    assert_eq!(sample(&final_text, "pdsgdm_jobs_state{state=\"completed\"}"), Some(2.0));
    std::fs::remove_dir_all(&state).unwrap();
}

fn fuzz_config() -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.algorithm = "pd-sgdm".into();
    c.workers = 4;
    c.steps = 30;
    c.eval_every = 10;
    c.workload = pdsgdm::config::WorkloadConfig::Quadratic {
        dim: 16,
        heterogeneity: 1.0,
        noise: 0.05,
    };
    c
}

/// `load_state` must return a clean `Err` — never panic — whatever
/// bytes it is fed. This is the property the daemon's restart path
/// leans on: a half-written drain checkpoint fails the resume with a
/// message instead of taking the service down.
#[test]
fn load_state_survives_truncation_at_every_offset() {
    let mut s = Session::build(SessionSpec::new(fuzz_config())).unwrap();
    s.run_until(StopCondition::Steps(30));
    let bytes = s.save_state();
    assert!(bytes.len() > 200, "fuzz needs a real checkpoint");

    // Every prefix in the header region, then a coarse sweep of the
    // interior, then every cut near the tail.
    let cuts: Vec<usize> = (0..bytes.len().min(96))
        .chain((96..bytes.len()).step_by(23))
        .chain(bytes.len().saturating_sub(48)..bytes.len())
        .collect();
    for cut in cuts {
        let mut fresh = Session::build(SessionSpec::new(fuzz_config())).unwrap();
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            fresh.load_state(&bytes[..cut])
        }));
        match outcome {
            Ok(Ok(())) => panic!("checkpoint truncated to {cut}/{} loaded cleanly", bytes.len()),
            Ok(Err(_)) => {}
            Err(_) => panic!("load_state panicked on truncation to {cut}/{}", bytes.len()),
        }
    }
}

#[test]
fn load_state_survives_garbage_and_bit_flips() {
    let mut s = Session::build(SessionSpec::new(fuzz_config())).unwrap();
    s.run_until(StopCondition::Steps(30));
    let bytes = s.save_state();

    // Pure garbage of assorted sizes (deterministic xorshift filler).
    let mut state = 0x9e3779b97f4a7c15u64;
    let mut rand_byte = move || {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        (state >> 32) as u8
    };
    for len in [0usize, 1, 7, 8, 9, 64, 1024, bytes.len()] {
        let garbage: Vec<u8> = (0..len).map(|_| rand_byte()).collect();
        let mut fresh = Session::build(SessionSpec::new(fuzz_config())).unwrap();
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            fresh.load_state(&garbage)
        }));
        match outcome {
            Ok(Ok(())) => panic!("{len} bytes of garbage loaded cleanly"),
            Ok(Err(_)) => {}
            Err(_) => panic!("load_state panicked on {len} bytes of garbage"),
        }
    }

    // Single-byte corruption sweep: flips may still load (a flipped
    // f32 payload byte is valid data) but must never panic. Skip the
    // magic — a corrupted magic is just the garbage case above.
    for pos in (8..bytes.len()).step_by(11) {
        let mut mutated = bytes.clone();
        mutated[pos] ^= 0xff;
        let mut fresh = Session::build(SessionSpec::new(fuzz_config())).unwrap();
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            fresh.load_state(&mutated)
        }));
        assert!(outcome.is_ok(), "load_state panicked on bit-flip at byte {pos}");
    }
}

/// End-to-end drain property at the service level: a daemon killed
/// mid-job (cooperative drain — the SIGTERM handler sets the same
/// flag) resumes from its manifest and produces byte-identical output.
#[test]
fn drained_daemon_restart_reproduces_uninterrupted_output() {
    let ref_state = temp_dir("e2e_ref");
    let state = temp_dir("e2e");
    let job = job_toml("e2e", 60_000);

    let make = |dir: &PathBuf| {
        Daemon::new(ServeConfig {
            listen: "127.0.0.1:0".into(),
            max_concurrent: 1,
            pool_threads: Some(2),
            state_dir: dir.display().to_string(),
            spool_dir: None,
            poll_ms: 10,
            exit_when_idle: true,
        })
        .unwrap()
    };

    let reference = make(&ref_state);
    reference.submit_toml(&job).unwrap();
    reference.run().unwrap();
    let want = std::fs::read_to_string(ref_state.join("out/e2e.csv")).unwrap();

    let daemon = make(&state);
    daemon.submit_toml(&job).unwrap();
    std::thread::scope(|scope| {
        let handle = scope.spawn(|| daemon.run().unwrap());
        while daemon.registry().steps_total("e2e") < 500 {
            std::thread::sleep(Duration::from_millis(1));
        }
        daemon.request_drain();
        handle.join().unwrap();
    });
    if daemon.queue().snapshot()[0].state == JobState::Drained {
        assert!(state.join("drain.json").is_file());
        let restarted = make(&state);
        restarted.run().unwrap();
        let snap = restarted.queue().snapshot();
        assert_eq!(snap[0].state, JobState::Completed, "{:?}", snap[0].error);
    }
    let got = std::fs::read_to_string(state.join("out/e2e.csv")).unwrap();
    assert_eq!(want, got, "drain + resume must be bit-identical");
    std::fs::remove_dir_all(&state).unwrap();
    std::fs::remove_dir_all(&ref_state).unwrap();
}
