//! Checkpoint/resume acceptance tests for the `Session` driver:
//!
//! * for **every** algorithm in `ALL_NAMES`, a run interrupted at T/2,
//!   checkpointed to the `PDSGDM02` format, and resumed into a freshly
//!   built session reproduces the uninterrupted run's trace — and final
//!   worker iterates — **bit-identically** (noisy gradients included, so
//!   RNG-stream restoration is load-bearing, not decorative);
//! * the same holds on the MLP workload, where resume additionally has
//!   to restore every worker's batch-sampler order/cursor/stream;
//! * `StopCondition::CommBudgetMb` halts within one comm round of the
//!   budget;
//! * v1→v2 forward compat: legacy `PDSGDM01` files still load as
//!   x̄-only, and v2 files satisfy x̄-only consumers too;
//! * the `eval_every == 0` division-by-zero panic in the old driver loop
//!   is gone (endpoints-only semantics instead).

use pdsgdm::algorithms::{Algorithm as _, ALL_NAMES};
use pdsgdm::config::{ExperimentConfig, WorkloadConfig};
use pdsgdm::coordinator::{
    load_checkpoint, run, save_checkpoint, RunOpts, Session, SessionSpec, StopCondition,
};
use pdsgdm::metrics::Trace;

fn quadratic_config(algorithm: &str) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.algorithm = algorithm.into();
    c.workers = 4;
    c.steps = 60;
    c.eval_every = 10;
    c.seed = 77;
    // noise > 0: a resume that fails to restore the per-worker gradient
    // RNG streams cannot reproduce the trace bits.
    c.workload = WorkloadConfig::Quadratic { dim: 16, heterogeneity: 1.0, noise: 0.2 };
    c.hyper.lr = pdsgdm::optim::LrSchedule::Constant { eta: 0.02 };
    c
}

fn mlp_config(algorithm: &str) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.algorithm = algorithm.into();
    c.workers = 4;
    c.steps = 40;
    c.eval_every = 10;
    c.seed = 5;
    c.workload = WorkloadConfig::Mlp { n: 400, dim: 8, classes: 3, hidden: 8, batch: 8 };
    c.hyper.lr = pdsgdm::optim::LrSchedule::Constant { eta: 0.05 };
    c
}

fn assert_traces_bit_identical(name: &str, a: &Trace, b: &Trace) {
    assert_eq!(a.label, b.label, "{name}");
    assert_eq!(a.points.len(), b.points.len(), "{name}: point counts differ");
    for (pa, pb) in a.points.iter().zip(&b.points) {
        assert_eq!(pa.step, pb.step, "{name}");
        let t = pa.step;
        assert_eq!(pa.loss.to_bits(), pb.loss.to_bits(), "{name}: loss @ step {t}");
        assert_eq!(
            pa.accuracy.to_bits(),
            pb.accuracy.to_bits(),
            "{name}: accuracy @ step {t}"
        );
        assert_eq!(
            pa.comm_mb.to_bits(),
            pb.comm_mb.to_bits(),
            "{name}: comm_mb @ step {t}"
        );
        assert_eq!(
            pa.consensus.to_bits(),
            pb.consensus.to_bits(),
            "{name}: consensus @ step {t}"
        );
        assert_eq!(
            pa.grad_norm_sq.to_bits(),
            pb.grad_norm_sq.to_bits(),
            "{name}: grad_norm_sq @ step {t}"
        );
        assert_eq!(
            pa.sim_seconds.to_bits(),
            pb.sim_seconds.to_bits(),
            "{name}: sim_seconds @ step {t}"
        );
    }
}

/// Run `cfg` uninterrupted; then run it to T/2, checkpoint, rebuild a
/// fresh session, resume, finish — and demand bitwise equality.
fn check_resume_matches(cfg: ExperimentConfig) {
    let name = cfg.algorithm.clone();
    let total = cfg.steps;
    let half = total / 2;

    let mut straight = Session::build(SessionSpec::new(cfg.clone())).unwrap();
    straight.run_until(StopCondition::Steps(total));

    let mut first = Session::build(SessionSpec::new(cfg.clone())).unwrap();
    first.run_until(StopCondition::Steps(half));
    let ckpt = first.save_state();
    drop(first); // the interrupted process is gone

    let mut resumed = Session::build(SessionSpec::new(cfg)).unwrap();
    resumed.load_state(&ckpt).unwrap_or_else(|e| panic!("{name}: {e}"));
    assert_eq!(resumed.steps_done(), half, "{name}");
    resumed.run_until(StopCondition::Steps(total));

    assert_traces_bit_identical(&name, straight.trace(), resumed.trace());
    // Beyond the trace: every worker's final iterate must agree bitwise.
    let (a, b) = (straight.algo(), resumed.algo());
    for k in 0..a.k() {
        let bits = |x: &[f32]| x.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(a.params(k)), bits(b.params(k)), "{name}: worker {k} iterate");
    }
}

#[test]
fn session_resume_bit_identical_for_every_algorithm_quadratic() {
    for name in ALL_NAMES {
        check_resume_matches(quadratic_config(name));
    }
}

#[test]
fn session_resume_bit_identical_on_mlp_batch_samplers() {
    // The MLP oracle's mutable state is its per-worker batch samplers
    // (shuffled order + cursor + RNG) — a resume that rebuilds them from
    // the seed instead of the checkpoint replays the wrong minibatches.
    for name in ["pd-sgdm", "cpd-sgdm", "d-sgd"] {
        check_resume_matches(mlp_config(name));
    }
}

#[test]
fn session_resume_from_off_cadence_interrupt_stays_bit_identical() {
    // Interrupting at a step that is NOT on the eval cadence records a
    // forced final TracePoint the uninterrupted run would never have.
    // load_state drops that trailing point, so the resumed trace still
    // matches the straight run bit-for-bit.
    let mut cfg = quadratic_config("pd-sgdm");
    cfg.eval_every = 20;
    let total = 60u64;
    let interrupt_at = 33u64; // off the 20-cadence, off the p=4 schedule

    let mut straight = Session::build(SessionSpec::new(cfg.clone())).unwrap();
    straight.run_until(StopCondition::Steps(total));

    let mut first = Session::build(SessionSpec::new(cfg.clone())).unwrap();
    first.run_until(StopCondition::Steps(interrupt_at));
    // the interrupted run's own trace ends with the forced point at 33
    assert_eq!(first.trace().points.last().unwrap().step, interrupt_at);
    let ckpt = first.save_state();
    drop(first);

    let mut resumed = Session::build(SessionSpec::new(cfg)).unwrap();
    resumed.load_state(&ckpt).unwrap();
    assert_eq!(resumed.steps_done(), interrupt_at);
    // trailing off-cadence point was dropped on load
    assert_eq!(resumed.trace().points.last().unwrap().step, 20);
    resumed.run_until(StopCondition::Steps(total));
    assert_traces_bit_identical("pd-sgdm(off-cadence)", straight.trace(), resumed.trace());
}

#[test]
fn session_resume_through_checkpoint_file() {
    let dir = std::env::temp_dir().join(format!("pdsgdm_resume_{}", std::process::id()));
    let path = dir.join("half.ckpt");

    let cfg = quadratic_config("cpd-sgdm");
    let mut straight = Session::build(SessionSpec::new(cfg.clone())).unwrap();
    straight.run_until(StopCondition::Steps(60));

    let mut first = Session::build(SessionSpec::new(cfg.clone())).unwrap();
    first.run_until(StopCondition::Steps(30));
    first.save(&path).unwrap();
    drop(first);

    let mut resumed =
        Session::build(SessionSpec::new(cfg).resume_from(&path)).unwrap();
    assert_eq!(resumed.steps_done(), 30);
    resumed.run_until(StopCondition::Steps(60));
    assert_traces_bit_identical("cpd-sgdm(file)", straight.trace(), resumed.trace());

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn user_pulled_eval_point_survives_resume() {
    // Only run_until's forced end-of-run eval is dropped on load; a
    // point the user deliberately recorded with eval_now() at the same
    // (off-cadence) step is part of the run's history and must survive.
    let cfg = quadratic_config("pd-sgdm"); // eval_every = 10
    let mut s = Session::build(SessionSpec::new(cfg.clone())).unwrap();
    for _ in 0..7 {
        s.step();
    }
    let p = s.eval_now();
    assert_eq!(p.step, 7);
    let ckpt = s.save_state();
    drop(s);

    let mut resumed = Session::build(SessionSpec::new(cfg)).unwrap();
    resumed.load_state(&ckpt).unwrap();
    assert_eq!(resumed.steps_done(), 7);
    assert_eq!(resumed.trace().points.last().unwrap().step, 7);
}

#[test]
fn resume_rejects_mismatched_config_fingerprint() {
    // Same algorithm/K/d but a different seed rebuilds a *different*
    // problem — resuming into it must fail loudly, not silently diverge.
    let cfg = quadratic_config("pd-sgdm");
    let mut s = Session::build(SessionSpec::new(cfg.clone())).unwrap();
    s.run_until(StopCondition::Steps(20));
    let ckpt = s.save_state();

    let mut other_seed = cfg.clone();
    other_seed.seed = 78;
    let mut t = Session::build(SessionSpec::new(other_seed)).unwrap();
    let err = t.load_state(&ckpt).unwrap_err();
    assert!(err.contains("config"), "{err}");

    let mut other_eta = cfg;
    other_eta.hyper.lr = pdsgdm::optim::LrSchedule::Constant { eta: 0.04 };
    let mut u = Session::build(SessionSpec::new(other_eta)).unwrap();
    let err = u.load_state(&ckpt).unwrap_err();
    assert!(err.contains("config"), "{err}");
}

#[test]
fn comm_budget_halts_within_one_round_of_budget() {
    // K=4 ring (degree 2), d=16 dense f32 gossip: one PD-SGDM round
    // moves 4 workers x 2 links x 64 bytes = 512 bytes.
    let round_bytes = 512u64;
    let budget_rounds = 5.5f64;
    let budget_mb = budget_rounds * round_bytes as f64 / (1024.0 * 1024.0);
    let mut cfg = quadratic_config("pd-sgdm");
    cfg.steps = 100_000;
    let mut s = Session::build(SessionSpec::new(cfg)).unwrap();
    s.run_until(StopCondition::Any(vec![
        StopCondition::Steps(100_000),
        StopCondition::CommBudgetMb(budget_mb),
    ]));
    let spent = s.comm_bytes();
    let budget_bytes = budget_mb * 1024.0 * 1024.0;
    assert!(spent as f64 >= budget_bytes, "halted under budget: {spent}");
    assert!(
        (spent as f64) < budget_bytes + round_bytes as f64,
        "overshot the budget by a full round or more: {spent} vs {budget_bytes}"
    );
    assert!(s.steps_done() < 100_000, "budget never bit");
}

#[test]
fn v1_checkpoints_still_load_as_xbar_only_and_v2_serves_both() {
    let dir = std::env::temp_dir().join(format!("pdsgdm_v1v2_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    // v1 file (old save path): loads as x̄, exactly as before.
    let v1 = dir.join("old.ckpt");
    let x: Vec<f32> = (0..32).map(|i| i as f32 * 0.5 - 3.0).collect();
    save_checkpoint(&v1, &x).unwrap();
    assert_eq!(load_checkpoint(&v1).unwrap(), x);

    // ...but cannot resume a session (x̄ is not full state).
    let mut s = Session::build(SessionSpec::new(quadratic_config("pd-sgdm"))).unwrap();
    let err = s.load(&v1).unwrap_err().to_string();
    assert!(err.contains("x̄") || err.contains("PDSGDM01"), "{err}");

    // v2 file: resumes (above) AND still serves x̄-only consumers.
    let v2 = dir.join("new.ckpt");
    s.run_until(StopCondition::Steps(20));
    s.save(&v2).unwrap();
    assert_eq!(load_checkpoint(&v2).unwrap(), s.avg_params());

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn eval_every_zero_no_longer_panics_in_driver_loop() {
    // Regression: the old loop computed `(t + 1) % opts.eval_every` and
    // panicked with `eval_every == 0`. The config layer rejects it...
    let mut cfg = quadratic_config("pd-sgdm");
    cfg.eval_every = 0;
    assert!(cfg.validate().is_err());

    // ...and the driver itself now treats 0 as "endpoints only".
    let mut src = pdsgdm::grad::Quadratic::new(4, 8, 1.0, 0.1, 3);
    let g = pdsgdm::topology::Topology::Ring.build(4, 0);
    let w = pdsgdm::topology::mixing_matrix(&g, pdsgdm::topology::Weighting::UniformDegree);
    let mut net = pdsgdm::comm::Network::new(&g);
    let x0 = pdsgdm::grad::GradientSource::init(&src, 1);
    let mut algo = pdsgdm::algorithms::AlgorithmSpec::new("pd-sgdm", 4, x0)
        .mixing(w)
        .build()
        .unwrap();
    let trace = run(
        algo.as_mut(),
        &mut src,
        &mut net,
        RunOpts { steps: 12, eval_every: 0, verbose: false, ..Default::default() },
    );
    let steps: Vec<u64> = trace.points.iter().map(|p| p.step).collect();
    assert_eq!(steps, vec![0, 12]);
}
