//! Frame-decoder fuzz hardening (ISSUE 10 satellite): the socket
//! transport's decoders face bytes from the network, so truncated,
//! garbage, and bit-flipped inputs must all come back as clean `Err`
//! (or `Incomplete` for honest prefixes) — never a panic, never an
//! over-read, never an absurd allocation. Mirrors the
//! `Session::load_state` catch_unwind sweep from the checkpoint suite.

use std::panic::{catch_unwind, AssertUnwindSafe};

use pdsgdm::comm::transport::{
    decode_dense, decode_eval, decode_frame, encode_dense, encode_eval, encode_frame, Frame,
    FrameError, FrameKind, TransportCounters,
};

/// Deterministic byte stream for garbage inputs (no rand crate).
fn splitmix_bytes(seed: u64, n: usize) -> Vec<u8> {
    let mut s = seed;
    (0..n)
        .map(|_| {
            s = s.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = s;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            (z ^ (z >> 31)) as u8
        })
        .collect()
}

fn sample_frames() -> Vec<Frame> {
    let counters = TransportCounters { frames_sent: 3, bytes_sent: 999, ..Default::default() };
    vec![
        Frame::new(FrameKind::Hello, 3, 0, 0, b"tcp:127.0.0.1:4000".to_vec()),
        Frame::new(FrameKind::PeerTable, 0, 3, 0, b"0 tcp:h:1\n1 tcp:h:2\n".to_vec()),
        Frame::new(FrameKind::Dense, 1, 2, 17, encode_dense(&[1.0, -2.5, 3.25e-8, f32::MAX])),
        Frame::new(FrameKind::Heartbeat, 2, 1, 9, Vec::new()),
        Frame::new(FrameKind::Eval, 4, 0, 40, encode_eval(0.125, &[0.5; 7], &counters)),
        Frame::new(FrameKind::Bye, 5, 0, 99, Vec::new()),
    ]
}

/// Every truncation of a valid frame decodes to `Incomplete` (an honest
/// prefix wants more bytes) — never Ok, never a panic.
#[test]
fn truncations_at_every_offset_are_incomplete() {
    for f in sample_frames() {
        let bytes = encode_frame(&f);
        for cut in 0..bytes.len() {
            let slice = bytes[..cut].to_vec();
            let out = catch_unwind(AssertUnwindSafe(|| decode_frame(&slice)))
                .unwrap_or_else(|_| panic!("decode_frame panicked at truncation {cut}"));
            match out {
                Err(FrameError::Incomplete) => {}
                Err(FrameError::Corrupt(_)) => {
                    panic!("truncation {cut} of a valid frame reported Corrupt, not Incomplete")
                }
                Ok(_) => panic!("truncation {cut} decoded Ok from a partial frame"),
            }
        }
        // The untruncated frame round-trips.
        let (back, used) = decode_frame(&bytes).expect("full frame decodes");
        assert_eq!(used, bytes.len());
        assert_eq!(back.kind, f.kind);
        assert_eq!((back.from, back.to, back.step), (f.from, f.to, f.step));
        assert_eq!(back.payload, f.payload);
    }
}

/// Flipping any single bit of a frame must yield a clean outcome:
/// `Corrupt` (CRC or structure check caught it), `Incomplete` (the
/// length prefix shrank/grew), or — only for bits inside the length
/// prefix that grew it — a request for more bytes. Never a panic, and
/// never an Ok whose bytes differ from what was sent.
#[test]
fn single_bit_flips_never_panic_and_never_pass_silently() {
    for f in sample_frames() {
        let bytes = encode_frame(&f);
        for byte_idx in 0..bytes.len() {
            for bit in 0..8 {
                let mut m = bytes.clone();
                m[byte_idx] ^= 1 << bit;
                let out = catch_unwind(AssertUnwindSafe(|| decode_frame(&m)))
                    .unwrap_or_else(|_| {
                        panic!("decode_frame panicked on bit flip {byte_idx}:{bit}")
                    });
                if let Ok((back, _)) = out {
                    // A flip confined to the length prefix can re-frame
                    // the stream; anything that decodes Ok must still
                    // have passed its own CRC over the *mutated* bytes,
                    // so it cannot silently equal the original frame.
                    assert!(
                        back.payload != f.payload
                            || back.kind != f.kind
                            || back.from != f.from
                            || back.to != f.to
                            || back.step != f.step,
                        "bit flip {byte_idx}:{bit} decoded as the original frame"
                    );
                }
            }
        }
    }
}

/// Random garbage at every length: clean Err/Incomplete, no panic.
#[test]
fn garbage_streams_never_panic() {
    for seed in 0..64u64 {
        let junk = splitmix_bytes(seed, 256);
        for cut in 0..=junk.len() {
            let slice = junk[..cut].to_vec();
            let r = catch_unwind(AssertUnwindSafe(|| decode_frame(&slice)))
                .unwrap_or_else(|_| panic!("decode_frame panicked on garbage seed={seed} cut={cut}"));
            // Ok is astronomically unlikely (CRC32) but would be legal;
            // what matters is no panic and no unbounded allocation.
            let _ = r;
        }
    }
}

/// A hostile length prefix (u32::MAX and friends) is rejected before
/// any allocation is sized by it.
#[test]
fn hostile_length_prefixes_are_rejected_cheaply() {
    for len in [u32::MAX, u32::MAX - 1, (1u32 << 28) + 1, 1u32 << 30] {
        let mut buf = len.to_le_bytes().to_vec();
        buf.extend_from_slice(&[0u8; 64]);
        match decode_frame(&buf) {
            Err(FrameError::Corrupt(msg)) => {
                assert!(msg.contains("exceeds cap"), "unexpected message: {msg}")
            }
            other => panic!("hostile length {len} not rejected: {other:?}"),
        }
    }
    // A length *below* the minimum body is equally structural garbage.
    let mut buf = 3u32.to_le_bytes().to_vec();
    buf.extend_from_slice(&[0u8; 64]);
    assert!(matches!(decode_frame(&buf), Err(FrameError::Corrupt(_))));
}

/// The payload decoders (dense vectors, eval reports, counter lists)
/// survive the same truncation + garbage sweeps.
#[test]
fn payload_decoders_survive_truncation_and_garbage() {
    let counters = TransportCounters {
        connect_retries: 1,
        peers_dead: 2,
        bytes_received: 1 << 40,
        ..Default::default()
    };
    let dense = encode_dense(&[1.0f32, 2.0, -0.5, 1e-20]);
    let eval = encode_eval(-3.5, &[9.0; 5], &counters);
    let enc = counters.encode();

    for (name, bytes) in [("dense", &dense), ("eval", &eval), ("counters", &enc)] {
        for cut in 0..bytes.len() {
            let slice = bytes[..cut].to_vec();
            let ok = catch_unwind(AssertUnwindSafe(|| match name {
                "dense" => decode_dense(&slice).map(|_| ()),
                "eval" => decode_eval(&slice).map(|_| ()),
                _ => TransportCounters::decode(&slice).map(|_| ()),
            }));
            assert!(ok.is_ok(), "{name} decoder panicked at truncation {cut}");
        }
    }
    for seed in 64..96u64 {
        let junk = splitmix_bytes(seed, 128);
        assert!(
            catch_unwind(AssertUnwindSafe(|| {
                let _ = decode_dense(&junk);
                let _ = decode_eval(&junk);
                let _ = TransportCounters::decode(&junk);
            }))
            .is_ok(),
            "payload decoder panicked on garbage seed {seed}"
        );
    }
    // And the valid encodings round-trip.
    assert_eq!(decode_dense(&dense).unwrap(), vec![1.0f32, 2.0, -0.5, 1e-20]);
    let (loss, x, c) = decode_eval(&eval).unwrap();
    assert_eq!(loss, -3.5);
    assert_eq!(x, vec![9.0f32; 5]);
    assert_eq!(c, counters);
}

/// Two frames concatenated decode one at a time with correct consumed
/// lengths — the stream decoder's actual usage pattern.
#[test]
fn concatenated_frames_decode_sequentially() {
    let frames = sample_frames();
    let mut stream = Vec::new();
    for f in &frames {
        stream.extend_from_slice(&encode_frame(f));
    }
    let mut off = 0;
    for f in &frames {
        let (back, used) = decode_frame(&stream[off..]).expect("next frame decodes");
        assert_eq!(back.kind, f.kind);
        assert_eq!(back.payload, f.payload);
        off += used;
    }
    assert_eq!(off, stream.len());
    assert!(matches!(decode_frame(&stream[off..]), Err(FrameError::Incomplete)));
}
