//! End-to-end socket-transport tests (ISSUE 10): spawn real `pdsgdm
//! worker` OS processes over loopback sockets and check the two
//! headline properties —
//!
//! 1. **Bit-identity**: a socket run reproduces the in-memory run's
//!    trace CSV byte-for-byte on the same seed (Unix sockets and TCP).
//! 2. **Graceful degradation**: killing a worker process mid-run
//!    completes with finite loss and nonzero peer-loss counters
//!    instead of hanging.
//!
//! The worker binary is the crate's own `pdsgdm` bin, resolved via
//! `CARGO_BIN_EXE_pdsgdm`, so `cargo test` builds it automatically.

use std::path::PathBuf;

use pdsgdm::comm::transport::run_coordinator;
use pdsgdm::config::{ExperimentConfig, TransportBackend, TransportConfig};
use pdsgdm::coordinator::{Session, SessionSpec, StopCondition};
use pdsgdm::metrics;

fn worker_exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_pdsgdm"))
}

/// A small, fast experiment: K=5 ring over the heterogeneous quadratic
/// (deterministic, no data generation cost), a few comm periods and
/// several eval points.
fn base_config(name: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::from_toml_str(&format!(
        r#"
        name = "{name}"
        algorithm = "pd-sgdm"
        workers = 5
        steps = 24
        eval_every = 6
        seed = 11
        topology = "ring"
        weighting = "metropolis"
        hyper.eta = 0.05
        hyper.mu = 0.9
        hyper.period = 3
        workload.kind = "quadratic"
        workload.dim = 12
        workload.heterogeneity = 0.5
        workload.noise = 0.05
        "#
    ))
    .expect("base config parses");
    cfg.out_dir = std::env::temp_dir().display().to_string();
    cfg
}

fn csv_of_trace(tag: &str, trace: &metrics::Trace) -> Vec<u8> {
    let path = std::env::temp_dir().join(format!("pdsgdm-ti-{tag}-{}.csv", std::process::id()));
    metrics::write_csv(&path, std::slice::from_ref(trace)).expect("write csv");
    let bytes = std::fs::read(&path).expect("read csv back");
    let _ = std::fs::remove_file(&path);
    bytes
}

/// In-memory reference run for the same config (transport stripped).
fn inproc_trace(mut cfg: ExperimentConfig) -> metrics::Trace {
    cfg.transport = None;
    let steps = cfg.steps;
    let mut session = Session::build(SessionSpec::new(cfg)).expect("build in-proc session");
    session.run_until(StopCondition::Steps(steps)).clone()
}

fn socket_run(cfg: &ExperimentConfig) -> pdsgdm::comm::transport::TransportRunOutcome {
    run_coordinator(cfg, &worker_exe(), false).expect("socket run completes")
}

#[test]
fn unix_socket_run_is_bit_identical_to_inproc() {
    let mut cfg = base_config("uds-bitid");
    cfg.transport = Some(TransportConfig {
        backend: TransportBackend::Unix,
        ..TransportConfig::default()
    });
    let outcome = socket_run(&cfg);
    let reference = inproc_trace(cfg);

    assert_eq!(
        outcome.trace.points.len(),
        reference.points.len(),
        "same evaluation cadence"
    );
    // CSV bytes are the contract (what the CI job diffs) …
    assert_eq!(
        csv_of_trace("uds", &outcome.trace),
        csv_of_trace("ref", &reference),
        "socket CSV differs from in-memory CSV"
    );
    // … and the floats behind them match bitwise, not just in print.
    for (s, r) in outcome.trace.points.iter().zip(reference.points.iter()) {
        assert_eq!(s.step, r.step);
        assert_eq!(s.loss.to_bits(), r.loss.to_bits(), "loss at step {}", s.step);
        assert_eq!(s.consensus.to_bits(), r.consensus.to_bits(), "consensus at {}", s.step);
        assert_eq!(s.comm_mb.to_bits(), r.comm_mb.to_bits(), "comm_mb at {}", s.step);
        assert_eq!(s.sim_seconds.to_bits(), r.sim_seconds.to_bits(), "sim_seconds at {}", s.step);
    }
    assert_eq!(outcome.peers_lost, 0, "healthy run loses nobody");
    assert!(outcome.counters.frames_sent > 0, "bytes actually moved on the wire");
    assert!(outcome.counters.bytes_sent > 0);
    assert_eq!(outcome.counters.crc_errors, 0);
    assert!(outcome.wall_seconds > 0.0);
}

#[test]
fn tcp_socket_run_is_bit_identical_to_inproc() {
    let mut cfg = base_config("tcp-bitid");
    cfg.steps = 12; // smoke-sized: UDS already covers the long leg
    cfg.eval_every = 4;
    cfg.transport = Some(TransportConfig::default()); // tcp backend
    let outcome = socket_run(&cfg);
    let reference = inproc_trace(cfg);
    assert_eq!(
        csv_of_trace("tcp", &outcome.trace),
        csv_of_trace("tcp-ref", &reference),
        "TCP CSV differs from in-memory CSV"
    );
    assert_eq!(outcome.peers_lost, 0);
}

/// Satellite: kill one worker process mid-run. The fabric must detect
/// the death (EOF/heartbeats), renormalize mixing over the survivors,
/// and finish with finite loss and visible peer-loss counters — no
/// hang, no panic.
#[test]
fn killed_worker_degrades_gracefully() {
    let mut cfg = base_config("kill-drill");
    cfg.steps = 30;
    cfg.eval_every = 6;
    let mut t = TransportConfig { backend: TransportBackend::Unix, ..TransportConfig::default() };
    // Kill worker 2 right after the step-12 reports are collected, and
    // keep the death-detection knobs tight so the test stays fast.
    t.kill_worker = Some((2, 12));
    t.heartbeat_ms = 100;
    t.heartbeat_misses = 3;
    t.round_timeout_ms = 10_000;
    cfg.transport = Some(t);

    let outcome = socket_run(&cfg);
    assert!(outcome.peers_lost >= 1, "the kill must be observed");
    assert!(outcome.counters.peers_dead >= 1, "peer-death counters must be nonzero");
    let last = outcome.trace.points.last().expect("run produced a final eval");
    assert_eq!(last.step, 30, "run completed all steps despite the kill");
    assert!(last.loss.is_finite(), "survivors' loss stayed finite: {}", last.loss);
    // Pre-kill prefix is still deterministic: it must match the
    // in-memory run up to the kill step.
    let reference = inproc_trace(cfg);
    for (s, r) in outcome.trace.points.iter().zip(reference.points.iter()) {
        if s.step > 12 {
            break;
        }
        assert_eq!(s.loss.to_bits(), r.loss.to_bits(), "pre-kill loss at step {}", s.step);
    }
}

/// The CLI path: `pdsgdm train --transport none` vs the socket run via
/// `run_coordinator` share one config file (what the CI smoke job
/// does, minus the process spawn for the in-memory leg).
#[test]
fn config_file_round_trips_through_worker_processes() {
    let cfg = base_config("cfg-roundtrip");
    // What run_coordinator writes for workers must re-parse to the same
    // experiment — the whole bit-identity story rests on this.
    let mut with_t = cfg.clone();
    with_t.transport = Some(TransportConfig { backend: TransportBackend::Unix, ..TransportConfig::default() });
    let toml = with_t.to_toml().expect("serializable");
    let back = ExperimentConfig::from_toml_str(&toml).expect("re-parses");
    assert_eq!(format!("{:?}", with_t), format!("{back:?}"));
}
