//! Wire-layer integration tests (ISSUE 2): byte-exact codecs for every
//! compression operator, the `wire_bytes == payload.len()` network
//! invariant, and the corrected busiest-worker α–β cost model.

use std::sync::Arc;

use pdsgdm::algorithms::{Algorithm, CpdSgdm, Hyper, PdSgd};
use pdsgdm::comm::{CostModel, Network};
use pdsgdm::compress::{self, Compressor, Sign};
use pdsgdm::coordinator::{run, RunOpts};
use pdsgdm::grad::{GradientSource, Quadratic};
use pdsgdm::optim::LrSchedule;
use pdsgdm::rng::Xoshiro256;
use pdsgdm::testing::forall;
use pdsgdm::topology::{mixing_matrix, Topology, Weighting};

const SPECS: &[&str] = &["sign", "top0.1", "rand0.25", "qsgd4", "qsgd1", "identity"];

fn hyper(eta: f32, p: u64, gamma: f32) -> Hyper {
    Hyper {
        lr: LrSchedule::Constant { eta },
        mu: 0.9,
        weight_decay: 0.0,
        period: p,
        gamma,
    }
}

#[test]
fn prop_every_operator_roundtrips_bit_identically() {
    // forall over random d and σ: compress → encode → decode reproduces
    // the dense decode bit-for-bit, and the buffer length matches both
    // the CompressedVec's wire_bytes and the closed-form encoded_bytes.
    forall(0x317E_C0DE, 40, |rng| {
        let d = 1 + rng.below(600);
        let sigma = [1e-3f32, 1.0, 250.0][rng.below(3)];
        let x = rng.normal_vec(d, sigma);
        for spec in SPECS {
            let op = compress::parse(spec).expect(spec);
            let q = op.compress(&x, rng);
            let bytes = op.encode(&q);
            assert_eq!(bytes.len(), q.wire_bytes, "{spec}: wire_bytes != encoded length");
            assert_eq!(bytes.len(), op.encoded_bytes(d), "{spec}: encoded_bytes(d) formula drifted");
            let back = op.decode(&bytes, d);
            assert_eq!(back.len(), d, "{spec}");
            for (i, (a, b)) in q.dense.iter().zip(&back).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{spec}: coord {i}/{d} decoded {b}, compressed {a}"
                );
            }
        }
    });
}

#[test]
fn network_charges_exactly_the_encoded_payload_length() {
    // The honor system is gone: a Message's wire cost is measured from
    // the buffer it carries.
    let g = Topology::Ring.build(4, 0);
    let mut net = Network::new(&g);
    let mut rng = Xoshiro256::seed_from_u64(11);
    let x = rng.normal_vec(1000, 1.0);
    for spec in SPECS {
        let op = compress::parse(spec).expect(spec);
        let before = net.total_bytes;
        let q = op.compress(&x, &mut rng);
        let bytes = Arc::new(op.encode(&q));
        net.broadcast_encoded(0, Arc::clone(&bytes));
        assert_eq!(
            net.total_bytes - before,
            2 * bytes.len() as u64, // ring degree 2
            "{spec}"
        );
        for to in [1usize, 3] {
            for msg in net.recv_all(to) {
                assert_eq!(msg.wire_bytes(), bytes.len(), "{spec}");
                assert_eq!(msg.payload.encoded().unwrap(), bytes.as_slice(), "{spec}");
            }
        }
        net.end_round();
    }
}

#[test]
fn star_sim_time_prices_the_hub_not_worker_zero_neighbors() {
    // K=8 star: the hub has degree 7, leaves degree 1. One full-precision
    // gossip round must cost 7 links of latency plus the hub's 7·4d bytes
    // of bandwidth — the documented busiest-worker α–β model. This pins
    // the corrected cost model in closed form.
    let k = 8;
    let d = 64;
    let steps = 10u64;
    let period = 2u64;
    let g = Topology::Star.build(k, 0);
    let w = mixing_matrix(&g, Weighting::Metropolis);
    let mut net = Network::new(&g);
    let mut src = Quadratic::new(k, d, 1.0, 0.0, 3);
    let mut algo = PdSgd::new(k, src.init(1), w, hyper(0.01, period, 0.4));
    let cm = CostModel::default();
    let opts = RunOpts { steps, eval_every: 5, cost_model: cm, verbose: false };
    let trace = run(&mut algo, &mut src, &mut net, opts);

    let rounds = (steps / period) as f64;
    let hub_links = (k - 1) as f64;
    let hub_bytes = hub_links * (4 * d) as f64;
    let expect = steps as f64 * cm.step_seconds
        + rounds * (hub_links * cm.alpha + hub_bytes / cm.beta);
    let got = trace.points.last().unwrap().sim_seconds;
    assert!(
        (got - expect).abs() < 1e-9,
        "star sim-seconds {got}, busiest-worker model predicts {expect}"
    );
}

#[test]
fn tiny_compressed_payloads_do_not_truncate_to_zero_bandwidth() {
    // Sign at d=4 is a 5-byte message; the old integer division
    // (bytes / (k · links)) floored the per-link bytes to 0 and the
    // simulated time silently lost its bandwidth term. With f64
    // accounting the term is small but exactly present.
    let k = 8;
    let d = 4;
    let steps = 8u64;
    let period = 2u64;
    let g = Topology::Ring.build(k, 0);
    let w = mixing_matrix(&g, Weighting::UniformDegree);
    let mut net = Network::new(&g);
    let mut src = Quadratic::new(k, d, 1.0, 0.0, 5);
    let mut algo = CpdSgdm::new(k, src.init(2), w, hyper(0.01, period, 0.4), Box::new(Sign), 5);
    let cm = CostModel::default();
    let opts = RunOpts { steps, eval_every: 4, cost_model: cm, verbose: false };
    let trace = run(&mut algo, &mut src, &mut net, opts);

    let rounds = (steps / period) as f64;
    let msg_bytes = Sign.encoded_bytes(d) as f64; // 4 + ceil(4/8) = 5
    let busiest = 2.0 * msg_bytes; // ring degree 2
    let latency_only = steps as f64 * cm.step_seconds + rounds * 2.0 * cm.alpha;
    let expect = latency_only + rounds * busiest / cm.beta;
    let got = trace.points.last().unwrap().sim_seconds;
    assert!(got > latency_only, "bandwidth term truncated away: {got}");
    assert!(
        (got - expect).abs() < 1e-12,
        "sim-seconds {got}, cost model predicts {expect}"
    );
}

#[test]
fn cpd_sgdm_converges_through_the_real_decode_path() {
    // End-to-end: CPD-SGDM's x̂ updates now come from decoding the wire
    // bytes its neighbors sent. With a bit-exact codec the trajectory
    // must still reach the optimum (cf. the unit convergence tests).
    // Same seeds as the in-module convergence test, so a bit-exact codec
    // must reproduce its trajectory (and its passing threshold) exactly.
    let k = 8;
    let mut src = Quadratic::new(k, 16, 1.0, 0.05, 5);
    let opt = src.optimum();
    let g = Topology::Ring.build(k, 0);
    let w = mixing_matrix(&g, Weighting::UniformDegree);
    let mut net = Network::new(&g);
    let lr = LrSchedule::StepDecay {
        eta0: 0.02,
        factor: 0.1,
        milestones: vec![0.5, 0.75],
        total_steps: 2500,
    };
    let h = Hyper { lr, ..hyper(0.02, 4, 0.4) };
    let mut algo = CpdSgdm::new(k, src.init(2), w, h, Box::new(Sign), 2);
    for t in 0..2500 {
        algo.step(t, &mut src, &mut net);
    }
    let err = {
        let xbar = algo.avg_params();
        xbar.iter()
            .zip(&opt)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
    };
    assert!(err < 0.35, "x̄ is {err} from x* through the wire codec path");
}

#[test]
fn sign_wire_reduction_is_32x_in_measured_buffer_lengths() {
    // Acceptance criterion: the ~32x Sign saving measured against actual
    // payload lengths on the network, not charged formulas.
    let k = 8;
    let d = 10_000;
    let g = Topology::Ring.build(k, 0);
    let w = mixing_matrix(&g, Weighting::UniformDegree);
    let mut net = Network::new(&g);
    let mut src = Quadratic::new(k, d, 1.0, 0.1, 8);
    let mut algo = CpdSgdm::new(k, src.init(5), w.clone(), hyper(0.01, 4, 0.4), Box::new(Sign), 5);
    for t in 0..8 {
        algo.step(t, &mut src, &mut net);
    }
    let compressed = net.total_bytes;
    assert!(compressed > 0, "compressed run sent nothing");

    let g2 = Topology::Ring.build(k, 0);
    let mut net2 = Network::new(&g2);
    let mut full = PdSgd::new(k, src.init(5), w, hyper(0.01, 4, 0.4));
    for t in 0..8 {
        full.step(t, &mut src, &mut net2);
    }
    let dense = net2.total_bytes;
    let ratio = dense as f64 / compressed as f64;
    assert!(ratio > 25.0, "sign should be ~32x smaller on the wire: {dense} vs {compressed}");
    assert!(ratio < 40.0, "ratio {ratio} implausibly large for 1-bit signs");
}
