//! Minimal in-repo stand-in for the `anyhow` crate.
//!
//! The build environment is fully offline (no crates.io), so the repo
//! vendors the narrow slice of the anyhow API its coordinator actually
//! uses: the string-backed [`Error`] type, the [`Result`] alias, the
//! [`anyhow!`] / [`bail!`] macros, and the [`Context`] extension trait.
//! Error values are flattened to a single display string at construction
//! (no source chain / backtrace machinery) — every use in this repo only
//! ever formats the error for a human, so nothing is lost.

use std::fmt;

/// String-backed error value. Construct via [`Error::msg`], the
/// [`anyhow!`] macro, or `?` on any `std::error::Error`.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(msg: M) -> Self {
        Self { msg: msg.to_string() }
    }

    /// anyhow parity: wrap a concrete std error.
    pub fn new<E: std::error::Error>(e: E) -> Self {
        Self::msg(&e)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` prints the full context chain in real anyhow; our chain
        // is pre-flattened into `msg`, so both forms print the same.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// `?` conversion from any std error. `Error` itself deliberately does
// NOT implement `std::error::Error` (exactly like real anyhow), which is
// what keeps this blanket impl coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Self::msg(&e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(|| ..)` on any displayable error.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => { $crate::Error::msg(format!($msg)) };
    ($err:expr $(,)?) => { $crate::Error::msg($err) };
    ($fmt:expr, $($arg:tt)*) => { $crate::Error::msg(format!($fmt, $($arg)*)) };
}

/// `return Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn macros_and_context_compose() {
        let base: Result<()> = Err(anyhow!("base {}", 7));
        let wrapped = base.context("outer");
        let msg = wrapped.unwrap_err().to_string();
        assert_eq!(msg, "outer: base 7");

        fn bails(flag: bool) -> Result<u32> {
            if flag {
                bail!("flagged");
            }
            Ok(1)
        }
        assert_eq!(bails(false).unwrap(), 1);
        assert_eq!(bails(true).unwrap_err().to_string(), "flagged");
    }

    #[test]
    fn anyhow_accepts_displayable_expression() {
        let s = String::from("plain string error");
        let e = anyhow!(s);
        assert_eq!(e.to_string(), "plain string error");
        // alternate formatting prints the same flattened chain
        assert_eq!(format!("{e:#}"), "plain string error");
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: std::result::Result<u32, std::fmt::Error> = Ok(3);
        let got = ok.with_context(|| -> String { panic!("must not evaluate") }).unwrap();
        assert_eq!(got, 3);
    }
}
